"""Paper Table 6 + Table 7 + Fig 12: DNN convergence/accuracy, TFIP vs LIRS.

The dataset is stored CLASS-SORTED on disk (ImageNet-style layout): a
bounded shuffle queue (TFIP) then yields class-skewed batches, while LIRS
mixes globally every epoch.  Three "model sizes" stand in for
AlexNet/OverFeat/VGG16.  Methodology follows §5.3.1: train TFIP to its
minimum validation loss, then count the epochs LIRS needs to reach it;
report final test accuracy for both.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cached
from repro.core.shuffler import LIRSShuffler, TFIPShuffler
from repro.dnn.mlp import MLPClassifier, make_clustered_data

N, DIM, CLASSES = 12000, 32, 20
BATCH = 100
E_MAX = 10
QUEUE = 600  # TFIP default window (paper used 10000 of 1.28M ~ 0.8%; 600/12000 = 5%)
MODELS = {
    "alexnet-like": (64,),
    "overfeat-like": (128, 64),
    "vgg-like": (256, 128, 64),
}
SEEDS = (0, 1, 2)


def _run(xs, ys, xval, yval, hidden, shuffler, epochs, seed):
    model = MLPClassifier(DIM, CLASSES, hidden=hidden, seed=seed)
    val_traj = []
    for e in range(epochs):
        for idx in shuffler.epoch_batches(e):
            model.train_batch(xs[idx], ys[idx])
        val_traj.append(model.loss(xval, yval))
    return model, np.minimum.accumulate(val_traj)


def run(force: bool = False):
    def compute():
        out = {}
        xs, ys, centers = make_clustered_data(N, DIM, CLASSES, seed=42, class_sorted=True, spread=1.0)
        xval, yval, _ = make_clustered_data(
            2000, DIM, CLASSES, seed=7, class_sorted=False, centers=centers
        )
        xte, yte, _ = make_clustered_data(
            4000, DIM, CLASSES, seed=99, class_sorted=False, centers=centers
        )
        ntr = N
        for name, hidden in MODELS.items():
            eps_l, acc_t, acc_l = [], [], []
            trajs = None
            for seed in SEEDS:
                tfip = TFIPShuffler(ntr, BATCH, queue_size=QUEUE, seed=seed)
                m_t, traj_t = _run(xs, ys, xval, yval, hidden, tfip, E_MAX, seed)
                lirs = LIRSShuffler(ntr, BATCH, seed=seed)
                m_l, traj_l = _run(xs, ys, xval, yval, hidden, lirs, E_MAX, seed)
                target = traj_t[-1]  # TFIP's min validation loss
                el = next(
                    (i + 1 for i, v in enumerate(traj_l) if v <= target), E_MAX + 1
                )
                eps_l.append(el)
                acc_t.append(m_t.accuracy(xte, yte))
                acc_l.append(m_l.accuracy(xte, yte))
                if trajs is None:
                    trajs = (traj_t.tolist(), traj_l.tolist())
            out[name] = {
                "epochs_tfip": E_MAX,
                "epochs_lirs_mean": float(np.mean(eps_l)),
                "epochs_lirs_per_seed": eps_l,
                "acc_tfip": float(np.mean(acc_t)),
                "acc_lirs": float(np.mean(acc_l)),
                "acc_improvement": float(np.mean(acc_l) - np.mean(acc_t)),
                "val_traj_tfip": trajs[0],
                "val_traj_lirs": trajs[1],
            }
        return out

    return cached("dnn_convergence", compute, force)


def rows():
    res = run()
    out = []
    for name, r in res.items():
        out.append(
            (
                f"dnn_convergence/{name}",
                0.0,
                f"epochs TFIP={r['epochs_tfip']} LIRS={r['epochs_lirs_mean']:.1f} "
                f"acc {r['acc_tfip']:.4f}->{r['acc_lirs']:.4f} "
                f"(+{100*r['acc_improvement']:.2f}pp)",
            )
        )
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
