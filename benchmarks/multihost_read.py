"""multihost_read — the distributed clairvoyant tier's aggregate-read
invariant, measured.

An ``H``-host cluster (``repro.prefetch.distributed``) serves the same
global LIRS batches a single host would, with each host caching only the
records *it* consumes and exporting them host-to-host next epoch.  The
benchmark sweeps ``H x {lru, belady}`` and checks the claims the design
makes:

* **aggregate-bytes invariant** — under belady, fleet storage reads per
  steady epoch sit at the distributed pigeonhole floor
  ``(1 - c_global) * n`` records (``n - sum(capacity_h)``) **exactly**,
  independent of how the capacity is sharded: remote traffic replaces
  storage reads one-for-one.  Consumer-side retention (each record is
  pushed at its use to its next-epoch consumer, the tier's occupancy
  trajectory feasible by construction) leaves no epoch-edge race to
  absorb — the measured excess is zero, and that is what the baseline
  gates.
* **local/remote split** — the served-records split tracks
  ``repro.storage.devices.distributed_hit_model``: total hit is
  capacity-shaped (the single-host closed form at ``c_global``) and the
  holder is uniform over hosts, so local ≈ hit/H, remote ≈ hit·(H−1)/H.
* **byte-identity** — the first global batch of the first measured
  epoch, served *in stream*, is byte-identical to a direct store read
  at every (H, policy) point (the full cross-product sweep lives in
  tests/test_multihost.py; this is the benchmark-side canary — served
  in stream because an out-of-stream serve desyncs the lookahead
  window and perturbs the read counts it shares a process with).
* **network pricing** — the measured remote bytes per epoch are priced
  over the ``NetworkModel`` link (25GbE default) next to the per-device
  storage-read time, showing when the cross-host tier pays: whenever
  ``t_link(remote_bytes) < t_device(storage_bytes_avoided)``.

Hygiene: ``peer_failures`` and ``push_errors`` must be 0 (all peers
healthy here) and remote accounting must balance — under belady every
cross-host record is a retention push the receiver banked
(``remote_hits == peer_refills``, nothing pulled), under lru every
remote hit is a peer-cache export (``remote_hits == remote_served``).
Emits JSON to benchmarks/results/multihost_read.json and harness CSV
rows; gated by benchmarks/compare.py.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import cached
from repro.core.shuffler import LIRSShuffler
from repro.prefetch.distributed import ClusterFetcher, make_cluster
from repro.storage.devices import (
    DEFAULT_NETWORK,
    STORAGE_MODELS,
    distributed_hit_model,
)
from repro.storage.record_store import RecordStore, RecordWriter

N_RECORDS = 8192
RECORD_BYTES = 256
BATCH = 512
FLEET_FRAC = 0.25          # c_global: fleet DRAM budget / dataset
HOSTS = [1, 2, 4]
POLICIES = ["lru", "belady"]
LOOKAHEAD = 8
WORKERS = 2
MEASURED_EPOCHS = 3        # after one warm-up epoch
TOTAL_EPOCHS = 1 + MEASURED_EPOCHS + 1  # placement keeps retaining


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp()
        path = f"{tmp}/multihost.rrec"
        rng = np.random.default_rng(0)
        with RecordWriter(path, record_size=RECORD_BYTES) as w:
            payload = rng.integers(
                0, 256, size=(N_RECORDS, RECORD_BYTES), dtype=np.uint8
            )
            for i in range(N_RECORDS):
                w.append(payload[i].tobytes())
        total_bytes = float(N_RECORDS * RECORD_BYTES)
        budget = int(FLEET_FRAC * total_bytes)
        sh = LIRSShuffler(
            N_RECORDS, BATCH, seed=1, avg_instance_bytes=RECORD_BYTES
        )
        ref = RecordStore(path)
        first_idx = next(sh.epoch_batches(1))
        ref_first = bytes(ref.read_batch_into(first_idx).reshape(-1))

        out = {
            "num_records": N_RECORDS,
            "record_bytes": RECORD_BYTES,
            "batch": BATCH,
            "fleet_budget_frac": FLEET_FRAC,
            "fleet_budget_bytes": budget,
            "lookahead": LOOKAHEAD,
            "measured_epochs": MEASURED_EPOCHS,
            "points": {},
        }

        for policy in POLICIES:
            for hosts in HOSTS:
                cl = make_cluster(
                    lambda: RecordStore(path),
                    sh,
                    hosts,
                    budget_bytes=budget,
                    lookahead=LOOKAHEAD,
                    gap_bytes=0,
                    workers=WORKERS,
                    background=True,
                    max_epochs=TOTAL_EPOCHS,
                    policy=policy,
                )
                fetcher = ClusterFetcher(cl)
                cap = cl.placement.aggregate_capacity()
                floor = cl.placement.expected_storage_reads()

                # warm-up epoch 0 populates the tier (and, H>1, pushes
                # the retention epoch 1 will gather)
                for idx in fetcher.batch_iter(0):
                    fetcher(idx)
                cl.drain()

                base = cl.aggregate_io()
                warm_first = None
                t0 = time.perf_counter()
                for e in range(1, 1 + MEASURED_EPOCHS):
                    for k, idx in enumerate(fetcher.batch_iter(e)):
                        got = fetcher(idx)
                        if e == 1 and k == 0:
                            # in-stream byte-identity canary (an
                            # out-of-stream serve would desync the
                            # lookahead window and perturb the counts)
                            warm_first = bytes(
                                np.asarray(got).reshape(-1)
                            )
                cl.drain()
                elapsed = time.perf_counter() - t0
                agg = cl.aggregate_io()
                d = {k: agg[k] - base[k] for k in agg}
                fetcher.close()

                served = MEASURED_EPOCHS * N_RECORDS
                storage_pe = d["storage_records"] / MEASURED_EPOCHS
                # the tier's hit rate is what it *avoided reading*; the
                # demand-path DRAM counter also counts records prefetched
                # from storage moments earlier, so derive from reads.
                # lookahead pins raise LRU's closed form (λ-correction),
                # same capping rule as benchmarks/prefetch.py
                lam = min(LOOKAHEAD * BATCH / N_RECORDS, FLEET_FRAC)
                hit_frac = 1.0 - storage_pe / N_RECORDS
                remote_frac = d["remote_hits"] / served
                model = distributed_hit_model(
                    FLEET_FRAC, hosts, policy=policy, window_frac=lam
                )
                remote_bytes_pe = d["remote_hit_bytes"] / MEASURED_EPOCHS
                storage_bytes_pe = d["storage_bytes"] / MEASURED_EPOCHS
                point = {
                    "hosts": hosts,
                    "policy": policy,
                    "fleet_capacity_records": cap,
                    "floor_records_per_epoch": floor,
                    "records_per_s": served / elapsed,
                    "epoch_s": elapsed / MEASURED_EPOCHS,
                    "storage_records_per_epoch": storage_pe,
                    "storage_bytes_per_epoch": storage_bytes_pe,
                    "aggregate_record_bytes_per_epoch": (
                        storage_pe * RECORD_BYTES
                    ),
                    "excess_records_vs_floor": storage_pe - floor,
                    "excess_read_bytes_vs_floor": max(
                        0.0, (storage_pe - floor) * RECORD_BYTES
                    ),
                    "hit_frac": hit_frac,
                    "local_hit_frac": hit_frac - remote_frac,
                    "remote_hit_frac": remote_frac,
                    "storage_frac": 1.0 - hit_frac,
                    "dram_demand_hits": d["local_hits"],
                    "model": model,
                    "model_abs_err": max(
                        abs((hit_frac - remote_frac) - model["local"]),
                        abs(remote_frac - model["remote"]),
                        abs((1.0 - hit_frac) - model["storage"]),
                    ),
                    "remote_bytes_per_epoch": remote_bytes_pe,
                    # belady: every cross-host record is a banked push
                    # (pull path idle); lru: every one is a peer export
                    "remote_accounting_balanced": (
                        d["remote_hits"] == d["peer_refills"]
                        and d["remote_served"] == 0
                        if policy == "belady" and hosts > 1
                        else d["remote_hits"] == d["remote_served"]
                    ),
                    "peer_pushes": d["peer_pushes"],
                    "push_errors": d["push_errors"],
                    "staged_records": d["staged_records"],
                    "peer_failures": d["peer_failures"],
                    "peer_errors": d["peer_errors"],
                    "degraded_batches": d["degraded_batches"],
                    "batches_identical_to_ref": warm_first == ref_first,
                    # what the cross-host tier buys on real devices: the
                    # avoided storage bytes priced per Table-2 device vs
                    # the same bytes over the peer link
                    "t_link_remote_s": DEFAULT_NETWORK.t_remote_read(
                        d["remote_hits"] / MEASURED_EPOCHS,
                        remote_bytes_pe,
                        inflight=DEFAULT_NETWORK.max_inflight,
                    ),
                    "t_device_avoided_s": {
                        name: dev.t_rand_read(
                            d["remote_hits"] / MEASURED_EPOCHS,
                            remote_bytes_pe,
                            queue_depth=WORKERS,
                        )
                        for name, dev in STORAGE_MODELS.items()
                    },
                }
                out["points"][f"{policy}_h{hosts}"] = point

        ref.close()

        bel = [
            out["points"][f"belady_h{h}"] for h in HOSTS
        ]
        # consumer-side retention leaves no epoch-edge race: belady
        # fleet storage reads hit the pigeonhole floor exactly
        excess_bound = 0
        out["headline"] = {
            # the invariant, fleet-wide: belady storage reads at the
            # pigeonhole floor exactly, at every host count
            "max_excess_records_vs_floor": max(
                p["excess_records_vs_floor"] for p in bel
            ),
            "excess_bound_records": excess_bound,
            "aggregate_invariant_ok": all(
                abs(p["excess_records_vs_floor"]) <= 1e-9 for p in bel
            ),
            "max_model_abs_err": max(
                p["model_abs_err"] for p in out["points"].values()
            ),
            "byte_mismatches": sum(
                not p["batches_identical_to_ref"]
                for p in out["points"].values()
            ),
            "peer_failures_total": sum(
                p["peer_failures"] for p in out["points"].values()
            ),
            "push_errors_total": sum(
                p["push_errors"] for p in out["points"].values()
            ),
            "accounting_imbalances": sum(
                not p["remote_accounting_balanced"]
                for p in out["points"].values()
            ),
        }
        return out

    return cached("multihost_read", compute, force)


def rows():
    res = run()
    out = []
    for key, p in res["points"].items():
        out.append(
            (
                f"multihost/{key}",
                1e6 / p["records_per_s"],
                f"{p['records_per_s']:,.0f} rec/s "
                f"storage={p['storage_records_per_epoch']:.0f}/ep "
                f"(floor {p['floor_records_per_epoch']}) "
                f"agg_B={p['aggregate_record_bytes_per_epoch']:.0f} "
                f"remote={p['remote_hit_frac']:.3f} "
                f"local={p['local_hit_frac']:.3f} "
                f"model_err={p['model_abs_err']:.3f} "
                f"identical={p['batches_identical_to_ref']}",
            )
        )
    h = res["headline"]
    worst = max(res["points"].values(), key=lambda p: p["epoch_s"])
    out.append(
        (
            "multihost/headline",
            1e6 * worst["epoch_s"] / res["num_records"],
            f"invariant_ok={h['aggregate_invariant_ok']} "
            f"max_excess={h['max_excess_records_vs_floor']:.0f} rec "
            f"(bound {h['excess_bound_records']}), "
            f"max_model_err={h['max_model_abs_err']:.3f}, "
            f"mismatches={h['byte_mismatches']}, "
            f"peer_failures={h['peer_failures_total']}",
        )
    )
    return out


if __name__ == "__main__":
    run(force=True)
    for r in rows():
        print(",".join(map(str, r)))
