"""prefetch — the tiered read path: clairvoyant prefetch + DRAM cache.

Because LIRS permutes *indexes*, the whole epoch's storage order is known
before the first read; this benchmark measures what the
``repro.prefetch`` subsystem buys when it exploits that:

* **hit-rate sweep** — steady-state DRAM-tier hit rate at several cache
  budgets (fractions of the dataset), measured at window-admission time
  (= storage reads avoided), against ``IOPlan.cache_hit_fraction``'s
  LRU-under-permutation closed form ``c + (1−c)·ln(1−c)``.  Full-range
  shuffling is adversarial for recency, so partial budgets hit far below
  ``c`` — the model has to track the measured curve, not ``budget/total``.
* **cold vs warm epoch throughput** — consumer-side wall time of one
  epoch through the ``InputPipeline``: the cold coalesced path
  (``store_fetch_fn``, every batch read from storage on demand) vs the
  warm tiered path (``PrefetchingFetcher`` after a warm-up epoch:
  resident records gathered from DRAM, misses prefetched ahead of demand
  by the background worker through the same pread pool).  The headline
  acceptance number is the warm/cold speedup at the full-coverage budget
  (any budget ≥ 25% of the dataset qualifies; the sweep shows where the
  crossover happens).  To be explicit about what partial budgets can
  show *on this box*: the benchmark file sits in the OS page cache and
  the consumer does zero compute, so direct "storage" reads are already
  memcpy-speed and a tier that still has to read ``(1−hit)·N`` records
  (plus one insert + one gather copy) cannot beat them — partial-budget
  sweep points honestly land below 1×.  Their value is the *avoided
  device I/O* on real storage, which ``modeled_epoch_read_s`` prices per
  Table 2 device via ``IOPlan.cache_hit_fraction``; the crossover to
  wall-clock wins happens once residency beats the copy overhead (full
  coverage here: demand becomes pure DRAM gather, 3-4×).
* **determinism spot-check** — first warm batch byte-identical to the
  cold path's.

Emits JSON to benchmarks/results/prefetch.json and harness CSV rows.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import cached
from repro.core.pipeline import InputPipeline, store_fetch_fn
from repro.core.shuffler import LIRSShuffler
from repro.prefetch.fetcher import PrefetchingFetcher
from repro.storage.devices import STORAGE_MODELS
from repro.storage.record_store import PAGE, RecordStore, RecordWriter

N_RECORDS = 32_768
RECORD_BYTES = 256
BATCH = 1024
WORKERS = 4
LOOKAHEAD = 8
GAP = 4 * PAGE
BUDGET_FRACS = [0.1, 0.25, 0.5, 1.0]
WARM_EPOCHS = 3   # measured epochs after the warm-up epoch
ACCEPT_MIN_BUDGET = 0.25


def _epoch_seconds(pipe: InputPipeline, epoch: int) -> float:
    t0 = time.perf_counter()
    for _ in pipe.epoch(epoch):
        pass
    return time.perf_counter() - t0


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp()
        path = f"{tmp}/prefetch.rrec"
        rng = np.random.default_rng(0)
        with RecordWriter(path, record_size=RECORD_BYTES) as w:
            payload = rng.integers(
                0, 256, size=(N_RECORDS, RECORD_BYTES), dtype=np.uint8
            )
            for i in range(N_RECORDS):
                w.append(payload[i].tobytes())
        store = RecordStore(path)
        total_bytes = float(N_RECORDS * RECORD_BYTES)
        sh = LIRSShuffler(
            N_RECORDS, BATCH, seed=1, avg_instance_bytes=RECORD_BYTES
        )

        # ---- cold baseline: coalesced demand reads, no DRAM tier
        cold_fetch = store_fetch_fn(store, gap_bytes=GAP, workers=WORKERS)
        cold_pipe = InputPipeline(
            lambda e: sh.epoch_batches(e), cold_fetch, prefetch=2
        )
        cold_s = min(_epoch_seconds(cold_pipe, e) for e in range(WARM_EPOCHS))
        first_idx = next(sh.epoch_batches(0))
        cold_first = bytes(cold_fetch(first_idx).reshape(-1))

        out = {
            "num_records": N_RECORDS,
            "record_bytes": RECORD_BYTES,
            "batch": BATCH,
            "workers": WORKERS,
            "lookahead": LOOKAHEAD,
            "gap_bytes": GAP,
            "cold_epoch_s": cold_s,
            "cold_records_per_s": N_RECORDS / cold_s,
            "budgets": {},
        }

        for frac in BUDGET_FRACS:
            budget = int(frac * total_bytes)
            fetcher = PrefetchingFetcher(
                store,
                sh,
                budget_bytes=budget,
                lookahead=LOOKAHEAD,
                gap_bytes=GAP,
                workers=WORKERS,
            )
            pipe = InputPipeline(fetcher.batch_iter, fetcher, prefetch=2)
            _epoch_seconds(pipe, 0)  # warm-up epoch: populate the tier
            fetcher.drain()
            sched = fetcher.scheduler
            p0, a0 = sched.planned_records, sched.admitted_records
            store.stats.reset()
            warm_s = min(
                _epoch_seconds(pipe, e) for e in range(1, 1 + WARM_EPOCHS)
            )
            # avoided-storage-reads rate over the measured epochs (window
            # dedups count as hits; their one read charges the first use)
            measured_hit = 1.0 - (sched.planned_records - p0) / max(
                1, sched.admitted_records - a0
            )
            window_records = sched.window_records
            storage_records = store.stats.batch_records  # pre-probe snapshot
            plan = sh.io_plan(
                total_bytes,
                is_sparse=False,
                coalesce_gap=GAP,
                queue_depth=WORKERS,
                cache_budget_bytes=budget,
                prefetch_window_bytes=window_records * RECORD_BYTES,
            )
            # determinism spot-check against the cold path (after the
            # timing and the stats snapshot: the out-of-stream probe
            # batch issues its own demand reads)
            warm_first = bytes(fetcher(first_idx).reshape(-1))
            fetcher.close()
            out["budgets"][f"{frac:.2f}"] = {
                "budget_bytes": budget,
                "warm_epoch_s": warm_s,
                "warm_records_per_s": N_RECORDS / warm_s,
                "warm_speedup_vs_cold": cold_s / warm_s,
                "window_records": window_records,
                "measured_hit_rate": measured_hit,
                "model_hit_rate": plan.cache_hit_fraction,
                "hit_rate_abs_err": abs(measured_hit - plan.cache_hit_fraction),
                "storage_records_per_epoch": storage_records / WARM_EPOCHS,
                "demand_cache_hits": fetcher.cache.hits,
                "prefetched_records": fetcher.prefetch_records,
                "batches_identical_to_cold": warm_first == cold_first,
                "modeled_epoch_read_s": {
                    name: dev.t_epoch_read(plan)
                    for name, dev in STORAGE_MODELS.items()
                },
            }

        # acceptance headline: best warm speedup among budgets covering
        # >= 25% of the dataset (the sweep shows the full curve)
        eligible = {
            f: e
            for f, e in out["budgets"].items()
            if float(f) >= ACCEPT_MIN_BUDGET
        }
        best = max(eligible.values(), key=lambda e: e["warm_speedup_vs_cold"])
        out["headline"] = {
            "warm_speedup_vs_cold": best["warm_speedup_vs_cold"],
            "at_budget_bytes": best["budget_bytes"],
            "at_budget_fraction": best["budget_bytes"] / total_bytes,
            "measured_hit_rate": best["measured_hit_rate"],
            "model_hit_rate": best["model_hit_rate"],
            "deterministic": all(
                e["batches_identical_to_cold"]
                for e in out["budgets"].values()
            ),
        }
        store.close()
        return out

    return cached("prefetch", compute, force)


def rows():
    res = run()
    out = [
        (
            "prefetch/cold",
            1e6 / res["cold_records_per_s"],
            f"{res['cold_records_per_s']:,.0f} rec/s coalesced demand reads",
        )
    ]
    for frac, e in res["budgets"].items():
        out.append(
            (
                f"prefetch/warm_budget{frac}",
                1e6 / e["warm_records_per_s"],
                f"{e['warm_records_per_s']:,.0f} rec/s "
                f"x{e['warm_speedup_vs_cold']:.1f} vs cold "
                f"hit={e['measured_hit_rate']:.3f} "
                f"(model {e['model_hit_rate']:.3f}) "
                f"identical={e['batches_identical_to_cold']}",
            )
        )
    h = res["headline"]
    out.append(
        (
            "prefetch/headline",
            1e6 / res["cold_records_per_s"] / h["warm_speedup_vs_cold"],
            f"x{h['warm_speedup_vs_cold']:.1f} warm vs cold at "
            f"{h['at_budget_fraction']:.0%} budget, "
            f"hit {h['measured_hit_rate']:.3f} vs model "
            f"{h['model_hit_rate']:.3f}, deterministic={h['deterministic']}",
        )
    )
    return out


if __name__ == "__main__":
    run(force=True)
    for r in rows():
        print(",".join(map(str, r)))
