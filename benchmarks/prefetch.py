"""prefetch — the tiered read path: clairvoyant prefetch + DRAM cache.

Because LIRS permutes *indexes*, the whole epoch's storage order is known
before the first read; this benchmark measures what the
``repro.prefetch`` subsystem buys when it exploits that:

* **policy sweep** — steady-state DRAM-tier hit rate at several cache
  budgets (fractions of the dataset) for both eviction policies,
  measured at window-admission time (= storage reads avoided), against
  the per-policy ``IOPlan.cache_hit_fraction`` closed forms: LRU's
  ``c + (1−c)·ln(1−c) (+ λ·c prefetch correction)`` and Belady's exact
  ``c``.  Full-range shuffling is adversarial for recency, so LRU hits
  far below ``c`` at partial budgets; Belady — farthest next use, exact
  under clairvoyance — serves one hit per slot per epoch, the pigeonhole
  bound, and must sit at or above LRU at **every** budget point.
* **planner axis** — every (budget, policy) point runs with the
  policy-aware prefetch planner on *and* off.  Planner-off reproduces
  the arrival-order-admission pathology at budgets narrower than a
  batch: ``rejected`` blows up, cross-epoch retention collapses, and the
  epoch reads ~every record from storage.  Planner-on must report
  ``rejected == 0`` at every point (both policies) and — under
  ``belady``, whose retention the planner restores — *strictly fewer
  storage record bytes* than planner-off wherever planner-off rejected
  inserts (LRU has almost no retention to restore at those budgets:
  its closed form is ~c²/2, so no byte bar is set for it); the
  wasted-bytes column reports each run's reads in excess of its
  policy's closed-form miss floor, against the
  ``wasted_read_fraction`` model (0 under belady-with-planner).
* **cold vs warm epoch throughput** — consumer-side wall time of one
  epoch through the ``InputPipeline``: the cold coalesced path
  (``store_fetch_fn``, every batch read from storage on demand) vs the
  warm tiered path (``PrefetchingFetcher`` after a warm-up epoch).  The
  headline acceptance number is the warm/cold speedup at the
  full-coverage budget (any budget ≥ 25% of the dataset qualifies).  To
  be explicit about what partial budgets can show *on this box*: the
  benchmark file sits in the OS page cache and the consumer does zero
  compute, so direct "storage" reads are already memcpy-speed and a tier
  that still has to read ``(1−hit)·N`` records cannot beat them —
  partial-budget sweep points honestly land below 1×.  Their value is
  the *avoided device I/O* on real storage, which ``modeled_epoch_read_s``
  prices per Table 2 device via ``IOPlan.cache_hit_fraction``.
* **determinism spot-check** — first warm batch byte-identical to the
  cold path's, for every policy.

Hygiene counters (``rejected``, ``stray_unpins``, ``scratch_copies``)
are surfaced per sweep point: stray unpins must be zero always, and
warm full-coverage epochs must run zero scratch copies (the ring
handoff).

Emits JSON to benchmarks/results/prefetch.json and harness CSV rows.
``python -m benchmarks.prefetch --policy-sweep`` prints the LRU-vs-Belady
hit-rate curves (and fails loudly if Belady ever dips below LRU).
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from benchmarks.common import cached
from repro.core.pipeline import InputPipeline, store_fetch_fn
from repro.core.shuffler import LIRSShuffler
from repro.prefetch.fetcher import PrefetchingFetcher
from repro.storage.devices import (
    STORAGE_MODELS,
    cache_hit_model,
    wasted_read_fraction,
)
from repro.storage.record_store import PAGE, RecordStore, RecordWriter

N_RECORDS = 32_768
RECORD_BYTES = 256
BATCH = 1024
WORKERS = 4
LOOKAHEAD = 8
GAP = 4 * PAGE
# 0.01/0.02 sit below the batch fraction (1024/32768): the regime where
# planner-off admission-by-arrival blows up ``rejected`` and forfeits
# retention — exactly what the planner axis is here to show
BUDGET_FRACS = [0.01, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0]
POLICIES = ["lru", "belady"]
PLANNERS = [True, False]
WARM_EPOCHS = 3   # measured epochs after the warm-up epoch
ACCEPT_MIN_BUDGET = 0.25


def _epoch_seconds(pipe: InputPipeline, epoch: int) -> float:
    t0 = time.perf_counter()
    for _ in pipe.epoch(epoch):
        pass
    return time.perf_counter() - t0


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp()
        path = f"{tmp}/prefetch.rrec"
        rng = np.random.default_rng(0)
        with RecordWriter(path, record_size=RECORD_BYTES) as w:
            payload = rng.integers(
                0, 256, size=(N_RECORDS, RECORD_BYTES), dtype=np.uint8
            )
            for i in range(N_RECORDS):
                w.append(payload[i].tobytes())
        store = RecordStore(path)
        total_bytes = float(N_RECORDS * RECORD_BYTES)
        sh = LIRSShuffler(
            N_RECORDS, BATCH, seed=1, avg_instance_bytes=RECORD_BYTES
        )

        # ---- cold baseline: coalesced demand reads, no DRAM tier
        cold_fetch = store_fetch_fn(store, gap_bytes=GAP, workers=WORKERS)
        cold_pipe = InputPipeline(
            lambda e: sh.epoch_batches(e), cold_fetch, prefetch=2
        )
        cold_s = min(_epoch_seconds(cold_pipe, e) for e in range(WARM_EPOCHS))
        first_idx = next(sh.epoch_batches(0))
        cold_first = bytes(cold_fetch(first_idx).reshape(-1))

        out = {
            "num_records": N_RECORDS,
            "record_bytes": RECORD_BYTES,
            "batch": BATCH,
            "workers": WORKERS,
            "lookahead": LOOKAHEAD,
            "gap_bytes": GAP,
            "cold_epoch_s": cold_s,
            "cold_records_per_s": N_RECORDS / cold_s,
            "budgets": {},
        }

        def run_point(frac, budget, policy, planner):
            fetcher = PrefetchingFetcher(
                store,
                sh,
                budget_bytes=budget,
                lookahead=LOOKAHEAD,
                gap_bytes=GAP,
                workers=WORKERS,
                policy=policy,
                planner=planner,
            )
            pipe = InputPipeline(fetcher.batch_iter, fetcher, prefetch=2)
            _epoch_seconds(pipe, 0)  # warm-up epoch: populate the tier
            fetcher.drain()
            sched = fetcher.scheduler
            p0, a0 = sched.planned_records, sched.admitted_records
            store.stats.reset()
            scr0 = fetcher.cache.scratch_copies
            warm_s = min(
                _epoch_seconds(pipe, e) for e in range(1, 1 + WARM_EPOCHS)
            )
            fetcher.drain()  # in-flight plans must charge these epochs
            # avoided-storage-reads rate over the measured epochs (window
            # dedups count as hits; their one read charges the first use;
            # planner-doomed records are charged — the demand path reads
            # them)
            measured_hit = 1.0 - (sched.planned_records - p0) / max(
                1, sched.admitted_records - a0
            )
            window_records = sched.window_records
            storage_records = store.stats.batch_records  # pre-probe
            plan = sh.io_plan(
                total_bytes,
                is_sparse=False,
                coalesce_gap=GAP,
                queue_depth=WORKERS,
                cache_budget_bytes=budget,
                prefetch_window_bytes=window_records * RECORD_BYTES,
                eviction_policy=policy,
            )
            # the run's reads in excess of its policy's closed-form miss
            # floor — what arrival-order admission wastes — vs the
            # wasted_read_fraction model (0 under a planner-filtered tier)
            lam = min(window_records / N_RECORDS, frac)
            floor_hit = cache_hit_model(frac, policy, window_frac=lam)
            wasted_frac = (
                storage_records / WARM_EPOCHS / N_RECORDS - (1.0 - floor_hit)
            )
            wasted_model = wasted_read_fraction(
                frac,
                policy,
                batch_frac=BATCH / N_RECORDS,
                planner=planner,
                window_frac=lam,
            )
            # determinism spot-check against the cold path (after the
            # timing and the stats snapshot: the out-of-stream probe
            # batch issues its own demand reads)
            warm_first = bytes(fetcher(first_idx).reshape(-1))
            fetcher.close()
            return {
                "planner": planner,
                "warm_epoch_s": warm_s,
                "warm_records_per_s": N_RECORDS / warm_s,
                "warm_speedup_vs_cold": cold_s / warm_s,
                "window_records": window_records,
                "measured_hit_rate": measured_hit,
                "model_hit_rate": plan.cache_hit_fraction,
                "hit_rate_abs_err": abs(
                    measured_hit - plan.cache_hit_fraction
                ),
                "storage_records_per_epoch": storage_records / WARM_EPOCHS,
                "storage_record_bytes_per_epoch": (
                    storage_records / WARM_EPOCHS * RECORD_BYTES
                ),
                "wasted_read_frac_measured": wasted_frac,
                "wasted_read_frac_model": wasted_model,
                "wasted_read_bytes_per_epoch": max(0.0, wasted_frac)
                * total_bytes,
                "demand_cache_hits": fetcher.cache.hits,
                "prefetched_records": fetcher.prefetch_records,
                "rejected": fetcher.cache.rejected,
                "planned_skips": fetcher.cache.planned_skips,
                "doomed_records": sched.doomed_records,
                "stray_unpins": fetcher.cache.stray_unpins,
                "warm_scratch_copies": fetcher.cache.scratch_copies - scr0,
                "batches_identical_to_cold": warm_first == cold_first,
                "modeled_epoch_read_s": {
                    name: dev.t_epoch_read(plan)
                    for name, dev in STORAGE_MODELS.items()
                },
            }

        for frac in BUDGET_FRACS:
            budget = int(frac * total_bytes)
            point = {"budget_bytes": budget}
            for policy in POLICIES:
                on = run_point(frac, budget, policy, planner=True)
                off = run_point(frac, budget, policy, planner=False)
                on["planner_off"] = off
                on["planner_saved_record_bytes_per_epoch"] = (
                    off["storage_record_bytes_per_epoch"]
                    - on["storage_record_bytes_per_epoch"]
                )
                point[policy] = on
            point["belady_minus_lru_hit"] = (
                point["belady"]["measured_hit_rate"]
                - point["lru"]["measured_hit_rate"]
            )
            out["budgets"][f"{frac:.2f}"] = point

        # acceptance headline: best warm speedup among budgets covering
        # >= 25% of the dataset (the sweep shows the full curve)
        eligible = [
            e[pol]
            for f, e in out["budgets"].items()
            for pol in POLICIES
            if float(f) >= ACCEPT_MIN_BUDGET
        ]
        best = max(eligible, key=lambda e: e["warm_speedup_vs_cold"])
        out["headline"] = {
            "warm_speedup_vs_cold": best["warm_speedup_vs_cold"],
            "measured_hit_rate": best["measured_hit_rate"],
            "model_hit_rate": best["model_hit_rate"],
            "belady_never_below_lru": all(
                e["belady_minus_lru_hit"] >= -1e-9
                for e in out["budgets"].values()
            ),
            "max_hit_rate_abs_err": max(
                e[pol]["hit_rate_abs_err"]
                for e in out["budgets"].values()
                for pol in POLICIES
            ),
            "stray_unpins_total": sum(
                e[pol]["stray_unpins"]
                for e in out["budgets"].values()
                for pol in POLICIES
            ),
            "deterministic": all(
                e[pol][k]
                for e in out["budgets"].values()
                for pol in POLICIES
                for k in ("batches_identical_to_cold",)
            )
            and all(
                e[pol]["planner_off"]["batches_identical_to_cold"]
                for e in out["budgets"].values()
                for pol in POLICIES
            ),
            "rejected_planner_on_total": sum(
                e[pol]["rejected"]
                for e in out["budgets"].values()
                for pol in POLICIES
            ),
            # at every budget where planner-off rejected inserts, the
            # planner must read strictly fewer storage record bytes
            "planner_strict_reduction_ok": all(
                e["belady"]["storage_record_bytes_per_epoch"]
                < e["belady"]["planner_off"]["storage_record_bytes_per_epoch"]
                for e in out["budgets"].values()
                if e["belady"]["planner_off"]["rejected"] > 0
            ),
            "max_wasted_frac_planner_on": max(
                e[pol]["wasted_read_frac_measured"]
                for e in out["budgets"].values()
                for pol in POLICIES
            ),
        }
        store.close()
        return out

    return cached("prefetch", compute, force)


def rows():
    res = run()
    out = [
        (
            "prefetch/cold",
            1e6 / res["cold_records_per_s"],
            f"{res['cold_records_per_s']:,.0f} rec/s coalesced demand reads",
        )
    ]
    for frac, e in res["budgets"].items():
        for pol in POLICIES:
            p = e[pol]
            out.append(
                (
                    f"prefetch/{pol}_budget{frac}",
                    1e6 / p["warm_records_per_s"],
                    f"{p['warm_records_per_s']:,.0f} rec/s "
                    f"x{p['warm_speedup_vs_cold']:.1f} vs cold "
                    f"hit={p['measured_hit_rate']:.3f} "
                    f"(model {p['model_hit_rate']:.3f}) "
                    f"rejected={p['rejected']} "
                    f"saved_B={p['planner_saved_record_bytes_per_epoch']:.0f} "
                    f"identical={p['batches_identical_to_cold']}",
                )
            )
    h = res["headline"]
    out.append(
        (
            "prefetch/headline",
            1e6 / res["cold_records_per_s"] / h["warm_speedup_vs_cold"],
            f"x{h['warm_speedup_vs_cold']:.1f} warm vs cold, "
            f"hit {h['measured_hit_rate']:.3f} vs model "
            f"{h['model_hit_rate']:.3f}, "
            f"belady>=lru={h['belady_never_below_lru']}, "
            f"max_model_err={h['max_hit_rate_abs_err']:.3f}, "
            f"deterministic={h['deterministic']}",
        )
    )
    return out


def policy_sweep(force: bool = True) -> bool:
    """Print the LRU-vs-Belady hit-rate curves vs budget (planner on, the
    default), plus the planner-off comparison: per-point wasted bytes and
    rejected inserts.  Returns whether the sweep meets the acceptance bar
    — Belady ≥ LRU at every point, measured ≈ model, byte-identity for
    {planner on, off} × {lru, belady}, zero stray unpins, ``rejected ==
    0`` at every planner-on point, and (belady) strictly fewer storage
    record bytes than planner-off wherever planner-off rejected."""
    res = run(force=force)
    print(f"{'budget':>8} {'lru meas':>9} {'lru model':>10} "
          f"{'bel meas':>9} {'bel model':>10} {'Δ(bel-lru)':>11} "
          f"{'off rej':>8} {'wasted_off':>11} {'saved_KiB':>10}")
    ok = True
    for frac, e in sorted(res["budgets"].items(), key=lambda kv: float(kv[0])):
        lru, bel = e["lru"], e["belady"]
        off = bel["planner_off"]
        print(
            f"{frac:>8} {lru['measured_hit_rate']:>9.4f} "
            f"{lru['model_hit_rate']:>10.4f} "
            f"{bel['measured_hit_rate']:>9.4f} "
            f"{bel['model_hit_rate']:>10.4f} "
            f"{e['belady_minus_lru_hit']:>+11.4f} "
            f"{off['rejected']:>8d} "
            f"{off['wasted_read_frac_measured']:>11.4f} "
            f"{bel['planner_saved_record_bytes_per_epoch'] / 1024:>10.0f}"
        )
        ok &= e["belady_minus_lru_hit"] >= -1e-9
        for pol in POLICIES:
            p = e[pol]
            ok &= p["hit_rate_abs_err"] <= max(
                0.05, 0.12 * p["model_hit_rate"]
            )
            ok &= p["batches_identical_to_cold"]
            ok &= p["planner_off"]["batches_identical_to_cold"]
            ok &= p["stray_unpins"] == 0
            ok &= p["planner_off"]["stray_unpins"] == 0
            # the planner's contract: no insert ever rejected, and waste
            # (reads beyond the closed-form miss floor) within tolerance
            # of the wasted_read_fraction model — 0 under belady
            ok &= p["rejected"] == 0
            ok &= (
                abs(
                    p["wasted_read_frac_measured"]
                    - p["wasted_read_frac_model"]
                )
                <= 0.05
            )
        if off["rejected"] > 0:
            ok &= (
                bel["storage_record_bytes_per_epoch"]
                < off["storage_record_bytes_per_epoch"]
            )
    h = res["headline"]
    print(
        f"headline: x{h['warm_speedup_vs_cold']:.2f} warm vs cold, "
        f"belady>=lru={h['belady_never_below_lru']}, "
        f"max_model_err={h['max_hit_rate_abs_err']:.4f}, "
        f"rejected_planner_on={h['rejected_planner_on_total']}, "
        f"planner_strict_reduction={h['planner_strict_reduction_ok']}, "
        f"deterministic={h['deterministic']}, sweep_ok={ok}"
    )
    return ok


if __name__ == "__main__":
    if "--policy-sweep" in sys.argv:
        sys.exit(0 if policy_sweep(force="--cached" not in sys.argv) else 1)
    run(force=True)
    for r in rows():
        print(",".join(map(str, r)))
