"""prefetch — the tiered read path: clairvoyant prefetch + DRAM cache.

Because LIRS permutes *indexes*, the whole epoch's storage order is known
before the first read; this benchmark measures what the
``repro.prefetch`` subsystem buys when it exploits that:

* **policy sweep** — steady-state DRAM-tier hit rate at several cache
  budgets (fractions of the dataset) for both eviction policies,
  measured at window-admission time (= storage reads avoided), against
  the per-policy ``IOPlan.cache_hit_fraction`` closed forms: LRU's
  ``c + (1−c)·ln(1−c) (+ λ·c prefetch correction)`` and Belady's exact
  ``c``.  Full-range shuffling is adversarial for recency, so LRU hits
  far below ``c`` at partial budgets; Belady — farthest next use, exact
  under clairvoyance — serves one hit per slot per epoch, the pigeonhole
  bound, and must sit at or above LRU at **every** budget point.
* **cold vs warm epoch throughput** — consumer-side wall time of one
  epoch through the ``InputPipeline``: the cold coalesced path
  (``store_fetch_fn``, every batch read from storage on demand) vs the
  warm tiered path (``PrefetchingFetcher`` after a warm-up epoch).  The
  headline acceptance number is the warm/cold speedup at the
  full-coverage budget (any budget ≥ 25% of the dataset qualifies).  To
  be explicit about what partial budgets can show *on this box*: the
  benchmark file sits in the OS page cache and the consumer does zero
  compute, so direct "storage" reads are already memcpy-speed and a tier
  that still has to read ``(1−hit)·N`` records cannot beat them —
  partial-budget sweep points honestly land below 1×.  Their value is
  the *avoided device I/O* on real storage, which ``modeled_epoch_read_s``
  prices per Table 2 device via ``IOPlan.cache_hit_fraction``.
* **determinism spot-check** — first warm batch byte-identical to the
  cold path's, for every policy.

Hygiene counters (``rejected``, ``stray_unpins``, ``scratch_copies``)
are surfaced per sweep point: stray unpins must be zero always, and
warm full-coverage epochs must run zero scratch copies (the ring
handoff).

Emits JSON to benchmarks/results/prefetch.json and harness CSV rows.
``python -m benchmarks.prefetch --policy-sweep`` prints the LRU-vs-Belady
hit-rate curves (and fails loudly if Belady ever dips below LRU).
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from benchmarks.common import cached
from repro.core.pipeline import InputPipeline, store_fetch_fn
from repro.core.shuffler import LIRSShuffler
from repro.prefetch.fetcher import PrefetchingFetcher
from repro.storage.devices import STORAGE_MODELS
from repro.storage.record_store import PAGE, RecordStore, RecordWriter

N_RECORDS = 32_768
RECORD_BYTES = 256
BATCH = 1024
WORKERS = 4
LOOKAHEAD = 8
GAP = 4 * PAGE
BUDGET_FRACS = [0.1, 0.25, 0.5, 1.0]
POLICIES = ["lru", "belady"]
WARM_EPOCHS = 3   # measured epochs after the warm-up epoch
ACCEPT_MIN_BUDGET = 0.25


def _epoch_seconds(pipe: InputPipeline, epoch: int) -> float:
    t0 = time.perf_counter()
    for _ in pipe.epoch(epoch):
        pass
    return time.perf_counter() - t0


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp()
        path = f"{tmp}/prefetch.rrec"
        rng = np.random.default_rng(0)
        with RecordWriter(path, record_size=RECORD_BYTES) as w:
            payload = rng.integers(
                0, 256, size=(N_RECORDS, RECORD_BYTES), dtype=np.uint8
            )
            for i in range(N_RECORDS):
                w.append(payload[i].tobytes())
        store = RecordStore(path)
        total_bytes = float(N_RECORDS * RECORD_BYTES)
        sh = LIRSShuffler(
            N_RECORDS, BATCH, seed=1, avg_instance_bytes=RECORD_BYTES
        )

        # ---- cold baseline: coalesced demand reads, no DRAM tier
        cold_fetch = store_fetch_fn(store, gap_bytes=GAP, workers=WORKERS)
        cold_pipe = InputPipeline(
            lambda e: sh.epoch_batches(e), cold_fetch, prefetch=2
        )
        cold_s = min(_epoch_seconds(cold_pipe, e) for e in range(WARM_EPOCHS))
        first_idx = next(sh.epoch_batches(0))
        cold_first = bytes(cold_fetch(first_idx).reshape(-1))

        out = {
            "num_records": N_RECORDS,
            "record_bytes": RECORD_BYTES,
            "batch": BATCH,
            "workers": WORKERS,
            "lookahead": LOOKAHEAD,
            "gap_bytes": GAP,
            "cold_epoch_s": cold_s,
            "cold_records_per_s": N_RECORDS / cold_s,
            "budgets": {},
        }

        for frac in BUDGET_FRACS:
            budget = int(frac * total_bytes)
            point = {"budget_bytes": budget}
            for policy in POLICIES:
                fetcher = PrefetchingFetcher(
                    store,
                    sh,
                    budget_bytes=budget,
                    lookahead=LOOKAHEAD,
                    gap_bytes=GAP,
                    workers=WORKERS,
                    policy=policy,
                )
                pipe = InputPipeline(fetcher.batch_iter, fetcher, prefetch=2)
                _epoch_seconds(pipe, 0)  # warm-up epoch: populate the tier
                fetcher.drain()
                sched = fetcher.scheduler
                p0, a0 = sched.planned_records, sched.admitted_records
                store.stats.reset()
                scr0 = fetcher.cache.scratch_copies
                warm_s = min(
                    _epoch_seconds(pipe, e) for e in range(1, 1 + WARM_EPOCHS)
                )
                # avoided-storage-reads rate over the measured epochs
                # (window dedups count as hits; their one read charges the
                # first use)
                measured_hit = 1.0 - (sched.planned_records - p0) / max(
                    1, sched.admitted_records - a0
                )
                window_records = sched.window_records
                storage_records = store.stats.batch_records  # pre-probe
                plan = sh.io_plan(
                    total_bytes,
                    is_sparse=False,
                    coalesce_gap=GAP,
                    queue_depth=WORKERS,
                    cache_budget_bytes=budget,
                    prefetch_window_bytes=window_records * RECORD_BYTES,
                    eviction_policy=policy,
                )
                # determinism spot-check against the cold path (after the
                # timing and the stats snapshot: the out-of-stream probe
                # batch issues its own demand reads)
                warm_first = bytes(fetcher(first_idx).reshape(-1))
                fetcher.close()
                point[policy] = {
                    "warm_epoch_s": warm_s,
                    "warm_records_per_s": N_RECORDS / warm_s,
                    "warm_speedup_vs_cold": cold_s / warm_s,
                    "window_records": window_records,
                    "measured_hit_rate": measured_hit,
                    "model_hit_rate": plan.cache_hit_fraction,
                    "hit_rate_abs_err": abs(
                        measured_hit - plan.cache_hit_fraction
                    ),
                    "storage_records_per_epoch": storage_records / WARM_EPOCHS,
                    "demand_cache_hits": fetcher.cache.hits,
                    "prefetched_records": fetcher.prefetch_records,
                    "rejected": fetcher.cache.rejected,
                    "stray_unpins": fetcher.cache.stray_unpins,
                    "warm_scratch_copies": fetcher.cache.scratch_copies - scr0,
                    "batches_identical_to_cold": warm_first == cold_first,
                    "modeled_epoch_read_s": {
                        name: dev.t_epoch_read(plan)
                        for name, dev in STORAGE_MODELS.items()
                    },
                }
            point["belady_minus_lru_hit"] = (
                point["belady"]["measured_hit_rate"]
                - point["lru"]["measured_hit_rate"]
            )
            out["budgets"][f"{frac:.2f}"] = point

        # acceptance headline: best warm speedup among budgets covering
        # >= 25% of the dataset (the sweep shows the full curve)
        eligible = [
            e[pol]
            for f, e in out["budgets"].items()
            for pol in POLICIES
            if float(f) >= ACCEPT_MIN_BUDGET
        ]
        best = max(eligible, key=lambda e: e["warm_speedup_vs_cold"])
        out["headline"] = {
            "warm_speedup_vs_cold": best["warm_speedup_vs_cold"],
            "measured_hit_rate": best["measured_hit_rate"],
            "model_hit_rate": best["model_hit_rate"],
            "belady_never_below_lru": all(
                e["belady_minus_lru_hit"] >= -1e-9
                for e in out["budgets"].values()
            ),
            "max_hit_rate_abs_err": max(
                e[pol]["hit_rate_abs_err"]
                for e in out["budgets"].values()
                for pol in POLICIES
            ),
            "stray_unpins_total": sum(
                e[pol]["stray_unpins"]
                for e in out["budgets"].values()
                for pol in POLICIES
            ),
            "deterministic": all(
                e[pol]["batches_identical_to_cold"]
                for e in out["budgets"].values()
                for pol in POLICIES
            ),
        }
        store.close()
        return out

    return cached("prefetch", compute, force)


def rows():
    res = run()
    out = [
        (
            "prefetch/cold",
            1e6 / res["cold_records_per_s"],
            f"{res['cold_records_per_s']:,.0f} rec/s coalesced demand reads",
        )
    ]
    for frac, e in res["budgets"].items():
        for pol in POLICIES:
            p = e[pol]
            out.append(
                (
                    f"prefetch/{pol}_budget{frac}",
                    1e6 / p["warm_records_per_s"],
                    f"{p['warm_records_per_s']:,.0f} rec/s "
                    f"x{p['warm_speedup_vs_cold']:.1f} vs cold "
                    f"hit={p['measured_hit_rate']:.3f} "
                    f"(model {p['model_hit_rate']:.3f}) "
                    f"identical={p['batches_identical_to_cold']}",
                )
            )
    h = res["headline"]
    out.append(
        (
            "prefetch/headline",
            1e6 / res["cold_records_per_s"] / h["warm_speedup_vs_cold"],
            f"x{h['warm_speedup_vs_cold']:.1f} warm vs cold, "
            f"hit {h['measured_hit_rate']:.3f} vs model "
            f"{h['model_hit_rate']:.3f}, "
            f"belady>=lru={h['belady_never_below_lru']}, "
            f"max_model_err={h['max_hit_rate_abs_err']:.3f}, "
            f"deterministic={h['deterministic']}",
        )
    )
    return out


def policy_sweep(force: bool = True) -> bool:
    """Print the LRU-vs-Belady hit-rate curves vs budget; returns whether
    the sweep meets the acceptance bar (Belady ≥ LRU at every point,
    measured ≈ model, byte-identity, zero stray unpins)."""
    res = run(force=force)
    print(f"{'budget':>8} {'lru meas':>9} {'lru model':>10} "
          f"{'bel meas':>9} {'bel model':>10} {'Δ(bel-lru)':>11}")
    ok = True
    for frac, e in sorted(res["budgets"].items(), key=lambda kv: float(kv[0])):
        lru, bel = e["lru"], e["belady"]
        print(
            f"{frac:>8} {lru['measured_hit_rate']:>9.4f} "
            f"{lru['model_hit_rate']:>10.4f} "
            f"{bel['measured_hit_rate']:>9.4f} "
            f"{bel['model_hit_rate']:>10.4f} "
            f"{e['belady_minus_lru_hit']:>+11.4f}"
        )
        ok &= e["belady_minus_lru_hit"] >= -1e-9
        for pol in POLICIES:
            p = e[pol]
            ok &= p["hit_rate_abs_err"] <= max(
                0.05, 0.12 * p["model_hit_rate"]
            )
            ok &= p["batches_identical_to_cold"]
            ok &= p["stray_unpins"] == 0
    h = res["headline"]
    print(
        f"headline: x{h['warm_speedup_vs_cold']:.2f} warm vs cold, "
        f"belady>=lru={h['belady_never_below_lru']}, "
        f"max_model_err={h['max_hit_rate_abs_err']:.4f}, "
        f"deterministic={h['deterministic']}, sweep_ok={ok}"
    )
    return ok


if __name__ == "__main__":
    if "--policy-sweep" in sys.argv:
        sys.exit(0 if policy_sweep(force="--cached" not in sys.argv) else 1)
    run(force=True)
    for r in rows():
        print(",".join(map(str, r)))
