"""fault_overhead — what the resilience scaffold costs on the clean path.

The ISSUE's bar: the zero-fault hot path (RREC v2 + retry policy +
``verify="auto"``) must stay within **2 %** of the bare v1 read path.
Variants, timed interleaved (best-of per variant, same index batches):

  * ``plain``        — v1 file, ``retry=None``, no checksum table: the
                       pre-resilience seed read path.  Informational only:
                       it is a *different file*, so page-cache temperature
                       differs from the v2 variants.
  * ``bare``         — the SAME v2 file with ``retry=None`` and
                       ``verify="off"``: the apples-to-apples denominator.
  * ``scaffold``     — v2 file, ``DEFAULT_RETRY``, ``verify="auto"``:
                       the production configuration.  The gated number is
                       ``scaffold_overhead_frac`` = scaffold/bare − 1.
  * ``injected_seam``— scaffold + a zero-rate :class:`FaultInjector`
                       under every pread (what chaos tests/benchmarks
                       pay even when no fault fires).  Informational.
  * ``verify_full``  — scaffold with every record checksummed per batch.
                       Informational (the integrity-paranoid mode).
  * ``chaos``        — scaffold + a ~3 % transient schedule and a tight
                       backoff, i.e. reads that actually retry and
                       re-verify.  Informational; also proves byte
                       identity under injection outside the test suite.

Every variant must return byte-identical batches (``byte_mismatches``
is gated at exactly 0 by benchmarks/compare.py).  Emits JSON to
benchmarks/results/fault_overhead.json and harness CSV rows.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from benchmarks.common import cached
from repro.storage.faults import (
    DEFAULT_RETRY,
    FaultInjector,
    FaultSpec,
    RetryPolicy,
)
from repro.storage.record_store import PAGE, RecordStore, write_records

N_RECORDS = 8_192
RECORD_SIZE = 4_096
BATCH = 1_024
N_BATCHES = 4
WORKERS = 4
GAP = 4 * PAGE
REPS = 7
OVERHEAD_GATE = 0.02  # the ISSUE's acceptance bar on scaffold_overhead_frac

CHAOS_SPEC = FaultSpec(
    seed=0, transient_rate=0.02, zero_read_rate=0.005, bitflip_rate=0.005
)
CHAOS_RETRY = RetryPolicy(max_retries=8, backoff_s=1e-4, backoff_cap_s=1e-3)


def _bench(stores, batches):
    """Interleaved best-of timing: one rep reads every batch through every
    variant before the next rep starts, so drift hits all variants alike."""
    best = {name: float("inf") for name in stores}
    for _ in range(REPS):
        for name, store in stores.items():
            t0 = time.perf_counter()
            for idx in batches:
                store.read_batch_into(idx, gap_bytes=GAP, workers=WORKERS)
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp(prefix="fault_overhead_")
        rng = np.random.default_rng(0)
        recs = [rng.bytes(RECORD_SIZE) for _ in range(N_RECORDS)]
        p1, p2 = f"{tmp}/v1.rrec", f"{tmp}/v2.rrec"
        write_records(p1, recs, record_size=RECORD_SIZE, checksums=False)
        write_records(p2, recs, record_size=RECORD_SIZE)

        stores = {
            "plain": RecordStore(p1, retry=None, verify="off"),
            "bare": RecordStore(p2, retry=None, verify="off"),
            "scaffold": RecordStore(p2, retry=DEFAULT_RETRY, verify="auto"),
            "injected_seam": RecordStore(
                p2, fault_injector=FaultInjector(FaultSpec()), verify="auto"
            ),
            "verify_full": RecordStore(p2, verify="full"),
            "chaos": RecordStore(
                p2,
                fault_injector=FaultInjector(CHAOS_SPEC),
                retry=CHAOS_RETRY,
                verify="full",
            ),
        }
        batches = [rng.permutation(N_RECORDS)[:BATCH] for _ in range(N_BATCHES)]

        # correctness before speed: every variant, byte-identical batches
        mismatches = 0
        want = [
            b"".join(recs[i] for i in idx) for idx in batches
        ]
        for store in stores.values():
            for idx, w in zip(batches, want):
                got = store.read_batch_into(
                    idx, gap_bytes=GAP, workers=WORKERS
                ).tobytes()
                mismatches += got != w
        chaos_stats = stores["chaos"].stats
        chaos_counters = {
            "injected": stores["chaos"]._injector.counters(),
            "retries": chaos_stats.retries,
            "checksum_failures": chaos_stats.checksum_failures,
            "degraded_batches": chaos_stats.degraded_batches,
        }

        best = _bench(stores, batches)
        total = BATCH * N_BATCHES
        out = {
            "num_records": N_RECORDS,
            "record_size": RECORD_SIZE,
            "batch": BATCH,
            "workers": WORKERS,
            "gap_bytes": GAP,
            "byte_mismatches": int(mismatches),
            "scaffold_overhead_frac": best["scaffold"] / best["bare"] - 1.0,
            "overhead_gate": OVERHEAD_GATE,
            "chaos_injection": chaos_counters,
        }
        for name, t in best.items():
            out[f"{name}_records_per_s"] = total / t
        for store in stores.values():
            store.close()
        return out

    return cached("fault_overhead", compute, force)


def rows():
    res = run()
    out = []
    bare = res["bare_records_per_s"]
    for name in (
        "plain", "bare", "scaffold", "injected_seam", "verify_full", "chaos"
    ):
        rps = res[f"{name}_records_per_s"]
        out.append(
            (
                f"fault_overhead/{name}",
                1e6 / rps,  # us per record
                f"{rps:,.0f} rec/s x{rps / bare:.3f} vs bare",
            )
        )
    out.append(
        (
            "fault_overhead/scaffold_overhead_frac",
            res["scaffold_overhead_frac"] * 1e6,  # harness wants a number
            f"{res['scaffold_overhead_frac']:+.4f} (gate < "
            f"{res['overhead_gate']:.2f}), byte_mismatches="
            f"{res['byte_mismatches']}",
        )
    )
    return out


if __name__ == "__main__":
    res = run(force=True)
    for r in rows():
        print(",".join(map(str, r)))
    bad = (
        res["byte_mismatches"] != 0
        or res["scaffold_overhead_frac"] >= OVERHEAD_GATE
    )
    sys.exit(1 if bad else 0)
