"""obs_overhead — what the observability layer costs on the read path.

The ISSUE's bar: with tracing *disabled* (the production default) the
instrumented hot path must stay within **2 %** of an uninstrumented
baseline, and with tracing *enabled* within **5 %**.  Python can't
compile the spans out, so the baseline stubs the obs entry points with
null no-ops — as close to compiled-out as the language gets.  Variants,
timed interleaved over identical coalesced batch reads (best-of per
variant, same index batches):

  * ``baseline``     — ``record_store``'s obs hooks swapped for null
                       stubs: no flag check, no clock, no histogram.
                       The apples-to-apples denominator.
  * ``tracing_off``  — real obs layer, tracing disabled.  The gated
                       number is ``tracing_off_overhead_frac`` =
                       tracing_off/baseline − 1 (< 2 %).
  * ``tracing_on``   — tracing enabled into a fresh per-rep ring.  The
                       gated number is ``tracing_on_overhead_frac``
                       (< 5 %).

Every variant must return byte-identical batches (``byte_mismatches``
is gated at exactly 0).  Also emits informational span-cost microbench
rows (ns per ``span()`` enter/exit, disabled vs enabled).

``--trace-demo PATH`` instead runs a small 2-host Belady training job
with tracing on and writes the Chrome trace-event JSON to PATH — the
nightly workflow uploads it as a browsable Perfetto artifact.

Emits JSON to benchmarks/results/obs_overhead.json and harness CSV rows.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from benchmarks.common import cached
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.storage import record_store
from repro.storage.record_store import PAGE, RecordStore, write_records

N_RECORDS = 8_192
RECORD_SIZE = 4_096
BATCH = 1_024
N_BATCHES = 8
WORKERS = 4
GAP = 4 * PAGE
REPS = 15
SPAN_ITERS = 100_000
OFF_GATE = 0.02  # the ISSUE's bar: tracing disabled costs < 2 %
ON_GATE = 0.05   # tracing enabled costs < 5 %


class _NullSpan:
    duration_s = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


_NULL_SPAN = _NullSpan()


class _NullTrace:
    """What a compiled-out build would leave behind: nothing."""

    @staticmethod
    def enabled():
        return False

    @staticmethod
    def span(name, cat="", args=None):
        return _NULL_SPAN

    @staticmethod
    def timed(name, cat="", args=None):
        return _NULL_SPAN

    @staticmethod
    def instant(name, cat="", args=None):
        return None


class _NullMetrics:
    @staticmethod
    def observe(name, seconds):
        return None


def _swap_obs(trace_mod, metrics_mod):
    old = (record_store._trace, record_store._metrics)
    record_store._trace = trace_mod
    record_store._metrics = metrics_mod
    return old


def _bench(store, batches):
    """Interleaved best-of timing: one rep reads every batch through every
    variant before the next rep starts (order rotated per rep so no
    variant always sits in the same drift phase), so box noise hits all
    variants alike.  One store, one page-cache temperature — only the
    obs layer varies."""

    def measure():
        t0 = time.perf_counter()
        for idx in batches:
            store.read_batch_into(idx, gap_bytes=GAP, workers=WORKERS)
        return time.perf_counter() - t0

    def run_baseline():
        old = _swap_obs(_NullTrace, _NullMetrics)
        try:
            return measure()
        finally:
            record_store._trace, record_store._metrics = old

    def run_off():
        obs_trace.disable()
        return measure()

    def run_on():
        obs_trace.resume()
        try:
            return measure()
        finally:
            obs_trace.disable()

    variants = [
        ("baseline", run_baseline),
        ("tracing_off", run_off),
        ("tracing_on", run_on),
    ]
    times = {name: [] for name, _ in variants}

    # one recorder for the whole bench: re-enabling per rep would hand the
    # measured region a fresh, never-touched ring, and the first-touch
    # page faults (not the spans) would then dominate the "overhead"
    obs_trace.enable()
    with obs_trace.span("bench/warmup", "bench"):
        pass  # pre-touch the calling thread's ring
    obs_trace.disable()
    try:
        for rep in range(REPS):
            got = {}
            for k in range(len(variants)):
                name, fn = variants[(rep + k) % len(variants)]
                got[name] = fn()
            for name, t in got.items():
                times[name].append(t)
    finally:
        obs_trace.disable()

    # the gated number pairs each rep's variants against the SAME rep's
    # baseline and takes the median ratio: box drift moves all three
    # adjacent measures together and cancels, where a ratio of
    # best-overall times rides whichever rep each minimum landed in
    best = {name: min(ts) for name, ts in times.items()}
    overhead = {
        name: float(np.median(
            [t / b for t, b in zip(times[name], times["baseline"])]
        )) - 1.0
        for name in ("tracing_off", "tracing_on")
    }
    return best, overhead


def _span_cost_ns(enabled: bool) -> float:
    """ns per span enter/exit — the primitive's own cost, informational."""
    if enabled:
        obs_trace.enable()
    else:
        obs_trace.disable()
    try:
        t0 = time.perf_counter_ns()
        for _ in range(SPAN_ITERS):
            with obs_trace.span("bench/span", "bench"):
                pass
        return (time.perf_counter_ns() - t0) / SPAN_ITERS
    finally:
        obs_trace.disable()


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp(prefix="obs_overhead_")
        rng = np.random.default_rng(0)
        recs = [rng.bytes(RECORD_SIZE) for _ in range(N_RECORDS)]
        path = f"{tmp}/data.rrec"
        write_records(path, recs, record_size=RECORD_SIZE)
        store = RecordStore(path)
        batches = [rng.permutation(N_RECORDS)[:BATCH] for _ in range(N_BATCHES)]

        # correctness before speed: byte-identical batches in every mode
        want = [b"".join(recs[i] for i in idx) for idx in batches]

        def canary():
            return sum(
                store.read_batch_into(
                    idx, gap_bytes=GAP, workers=WORKERS
                ).tobytes() != w
                for idx, w in zip(batches, want)
            )

        old = _swap_obs(_NullTrace, _NullMetrics)
        try:
            mismatches = canary()
        finally:
            record_store._trace, record_store._metrics = old
        obs_trace.disable()
        mismatches += canary()
        obs_trace.enable()
        try:
            mismatches += canary()
        finally:
            obs_trace.disable()

        best, overhead = _bench(store, batches)
        store.close()
        total = BATCH * N_BATCHES
        out = {
            "num_records": N_RECORDS,
            "record_size": RECORD_SIZE,
            "batch": BATCH,
            "workers": WORKERS,
            "reps": REPS,
            "byte_mismatches": int(mismatches),
            "tracing_off_overhead_frac": overhead["tracing_off"],
            "tracing_on_overhead_frac": overhead["tracing_on"],
            "off_gate": OFF_GATE,
            "on_gate": ON_GATE,
            "span_ns_disabled": _span_cost_ns(False),
            "span_ns_enabled": _span_cost_ns(True),
        }
        for name, t in best.items():
            out[f"{name}_records_per_s"] = total / t
        return out

    return cached("obs_overhead", compute, force)


def trace_demo(path: str) -> dict:
    """Run a tiny 2-host Belady training job with tracing on and write
    the Chrome trace-event JSON to ``path`` (nightly Perfetto artifact).
    Returns the run summary."""
    from repro.launch.train import main as train_main

    return train_main([
        "--smoke", "--num-records", "512", "--seq-len", "32",
        "--batch", "16", "--epochs", "3", "--cache-mb", "0.06",
        "--hosts", "2", "--eviction-policy", "belady",
        "--trace", path,
    ])


def rows():
    res = run()
    out = []
    base = res["baseline_records_per_s"]
    for name in ("baseline", "tracing_off", "tracing_on"):
        rps = res[f"{name}_records_per_s"]
        out.append(
            (
                f"obs_overhead/{name}",
                1e6 / rps,  # us per record
                f"{rps:,.0f} rec/s x{rps / base:.3f} vs baseline",
            )
        )
    for key, gate in (("tracing_off_overhead_frac", res["off_gate"]),
                      ("tracing_on_overhead_frac", res["on_gate"])):
        out.append(
            (
                f"obs_overhead/{key}",
                res[key] * 1e6,  # harness wants a number
                f"{res[key]:+.4f} (gate < {gate:.2f}), byte_mismatches="
                f"{res['byte_mismatches']}",
            )
        )
    out.append(
        (
            "obs_overhead/span_ns",
            res["span_ns_enabled"] / 1e3,
            f"{res['span_ns_disabled']:.0f} ns disabled / "
            f"{res['span_ns_enabled']:.0f} ns enabled per span",
        )
    )
    return out


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--trace-demo":
        summary = trace_demo(sys.argv[2])
        sys.exit(0 if summary.get("drift", {}).get("ok", True) else 1)
    res = run(force=True)
    for r in rows():
        print(",".join(map(str, r)))
    bad = (
        res["byte_mismatches"] != 0
        or res["tracing_off_overhead_frac"] >= OFF_GATE
        or res["tracing_on_overhead_frac"] >= ON_GATE
    )
    sys.exit(1 if bad else 0)
