"""Framework benchmark: measured input-pipeline throughput on this host.

Times (μs/record) for sequential vs random reads through the record store,
shuffler overhead per epoch, Eq. 1 overlap accounting through the real
pipeline, and the batch_gather kernel (interpret mode — functional timing
only; TPU is the performance target).
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import Timer, cached
from repro.core.pipeline import InputPipeline
from repro.core.shuffler import BMFShuffler, LIRSShuffler, TFIPShuffler
from repro.data.synthetic import decode_token_batch, make_token_dataset
from repro.storage.record_store import BatchBufferRing, RecordStore

N, SEQ, VOCAB, BATCH = 4096, 128, 1024, 64


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp()
        meta = make_token_dataset(f"{tmp}/tok.rrec", N, SEQ, VOCAB, seed=1)
        store = RecordStore(meta.path)
        out = {}

        # raw read paths
        with Timer() as t:
            store.read_range(0, N)
        out["seq_read_us_per_record"] = t.seconds / N * 1e6
        perm = np.random.default_rng(0).permutation(N)
        with Timer() as t:
            for i in perm:
                store.read(int(i))
        out["rand_read_us_per_record"] = t.seconds / N * 1e6

        # coalesced multi-queue batch path (the PR 1 engine)
        batches = perm.reshape(-1, BATCH)
        with Timer() as t:
            for bidx in batches:
                store.read_batch_into(bidx, workers=4)
        out["coalesced_read_us_per_record"] = t.seconds / N * 1e6

        # shuffler index-generation overhead (the LIRS "shuffle" itself)
        for name, sh in (
            ("lirs", LIRSShuffler(N, BATCH, seed=0)),
            ("lirs_feistel", LIRSShuffler(N, BATCH, seed=0, assignment="feistel")),
            ("bmf", BMFShuffler(N, N // BATCH, seed=0)),
            ("tfip", TFIPShuffler(N, BATCH, queue_size=512, seed=0)),
        ):
            with Timer() as t:
                for e in range(5):
                    for _ in sh.epoch_batches(e):
                        pass
            out[f"shuffle_us_per_record/{name}"] = t.seconds / (5 * N) * 1e6

        # end-to-end pipeline with compute overlap (Eq. 1 terms)
        def fetch(idx):
            return decode_token_batch(store.read_batch(idx), SEQ)

        pipe = InputPipeline(
            lambda e: LIRSShuffler(N, BATCH, seed=0).epoch_batches(e), fetch, prefetch=4
        )
        for batch in pipe.epoch(0):
            time.sleep(0.002)  # stand-in for a device step
        s = pipe.stats
        out["pipeline"] = {
            "t_load_s": s.t_load,
            "t_comp_s": s.t_comp,
            "t_overlap_s": s.t_overlap,
            "t_unhidden_load_s": s.t_wait,
            "overlap_fraction": s.t_overlap / max(s.t_load, 1e-9),
        }

        # multi-producer + coalesced reads + buffer-ring reuse
        ring = BatchBufferRing(BATCH, store.record_size, depth=6)
        def fetch_coalesced(idx):
            buf = ring.acquire(len(idx))
            return decode_token_batch(
                store.read_batch_into(idx, out=buf, workers=2), SEQ
            )
        pipe2 = InputPipeline(
            lambda e: LIRSShuffler(N, BATCH, seed=0).epoch_batches(e),
            fetch_coalesced,
            prefetch=4,
            num_producers=2,
            recycle_fn=lambda d: ring.recycle(d["tokens"]),
        )
        for batch in pipe2.epoch(0):
            time.sleep(0.002)
        s2 = pipe2.stats
        out["pipeline_mq"] = {
            "t_load_s": s2.t_load,
            "t_comp_s": s2.t_comp,
            "t_unhidden_load_s": s2.t_wait,
            "effective_epoch_s": s2.effective_epoch_time(),
            "ring_misses": ring.misses,
        }
        store.close()
        return out

    return cached("pipeline_throughput", compute, force)


def rows():
    res = run()
    out = []
    for k in (
        "seq_read_us_per_record",
        "rand_read_us_per_record",
        "coalesced_read_us_per_record",
    ):
        if k in res:
            out.append((f"pipeline/{k}", res[k], ""))
    for k, v in res.items():
        if k.startswith("shuffle_us_per_record/"):
            out.append((f"pipeline/{k}", v, ""))
    p = res["pipeline"]
    out.append(
        (
            "pipeline/overlap",
            p["t_unhidden_load_s"] * 1e6,
            f"load={p['t_load_s']:.3f}s comp={p['t_comp_s']:.3f}s "
            f"hidden={100*p['overlap_fraction']:.1f}%",
        )
    )
    if "pipeline_mq" in res:
        q = res["pipeline_mq"]
        out.append(
            (
                "pipeline/multi_queue",
                q["t_unhidden_load_s"] * 1e6,
                f"load={q['t_load_s']:.3f}s eff={q['effective_epoch_s']:.3f}s "
                f"ring_misses={q['ring_misses']}",
            )
        )
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
