"""Shared benchmark plumbing: result caching, CSV emission, tiny timers."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Callable, Dict

RESULTS_DIR = Path(__file__).resolve().parent / "results"
RESULTS_DIR.mkdir(parents=True, exist_ok=True)


def cached(name: str, fn: Callable[[], Dict[str, Any]], force: bool = False) -> Dict[str, Any]:
    """Run fn() once; cache its JSON-able result under results/<name>.json."""
    path = RESULTS_DIR / f"{name}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    out = fn()
    path.write_text(json.dumps(out, indent=1, sort_keys=True))
    return out


def emit_csv(rows):
    """Harness contract: print ``name,us_per_call,derived`` lines."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
