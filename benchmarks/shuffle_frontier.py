"""shuffle_frontier — the shuffle-strategy spectrum's quality-vs-I/O
trade, measured end to end.

LIRS pays one random read per record for a fully uniform per-epoch
permutation; a sequential scan (TFIP ``queue_size=1``) is free to read
and useless to SGD; the block strategies in between (CorgiPile, Corgi²)
buy randomness in units of their buffer span.  This benchmark walks a
nested chain along that spectrum —

    seq → CorgiPile(block 256, buffer 2 → 4 → 8 → 16) → LIRS

— and measures, per strategy, the three quantities the trade is made
of:

* **shuffle quality** (``repro.core.shuffle_quality``): within-batch
  bucket entropy (the per-step statistical quality SGD sees) and
  successor-gap entropy (the stream's sequential structure).  Along the
  chain the buffer span doubles each step, so within-batch entropy must
  be *strictly increasing* — that monotonicity is the frontier and is
  gated (``frontier_violations == 0``).
* **epoch I/O through the clairvoyant tier**: every strategy's stream
  runs through the real ``PrefetchingFetcher`` + ``TieredCache`` stack
  (belady, planner on, 25 % DRAM budget).  Storage *records* per epoch
  sit at the pigeonhole floor ``(1 − c)·n`` for **every** strategy —
  the tier only needs ``epoch_index_stream``, so clairvoyant retention
  is strategy-agnostic (gated: ``floor_violations == 0``).  What the
  spectrum changes is the *shape* of those reads: storage I/Os per
  epoch grow strictly along the chain (~12 for the scan, ~800 for
  LIRS at these sizes) as batches scatter over a wider span and stop
  coalescing.  Measured I/Os are the frontier's cost axis; the
  ``io_plan`` closed forms price the same epochs per Table-2 device
  alongside.
* **SVM convergence** (slow axis): LIBLINEAR-style dual coordinate
  descent (``repro.svm.dcd``) on a dense synthetic dataset, run
  block-wise over each strategy's batches.  The sequential scan's final
  relative objective must be worse than *every* shuffled strategy's
  (gated: ``convergence_inversions == 0``) — randomness quantized to
  even a two-block buffer already restores most of the convergence a
  full shuffle gives, which is the spectrum's reason to exist.

Extremes are gated too: the scan's within-batch entropy is ~0, and
Corgi² (random scatter at preprocess) matches LIRS's entropy at
block-sequential read cost — its point sits *off* the chain, below the
LIRS cost at the same quality, which is the hybrid's whole pitch.

Emits JSON to benchmarks/results/shuffle_frontier.json and harness CSV
rows; gated by benchmarks/compare.py (nightly job uploads the JSON).
"""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import Timer, cached
from repro.core.shuffle_quality import stream_quality
from repro.core.shuffler import (
    CorgiPileShuffler,
    CorgiSquaredShuffler,
    LIRSShuffler,
    TFIPShuffler,
)
from repro.data.synthetic import decode_dense_batch, make_classification_dataset
from repro.prefetch.fetcher import PrefetchingFetcher
from repro.storage.devices import (
    STORAGE_MODELS,
    block_cache_hit_model,
    cache_hit_model,
)
from repro.storage.record_store import PAGE, RecordStore, RecordWriter
from repro.svm.dcd import DCDSolver

N_RECORDS = 8192
RECORD_BYTES = 256
BATCH = 512
GAP = 4 * PAGE
WORKERS = 2
LOOKAHEAD = 8
BUDGET_FRAC = 0.25
MEASURED_EPOCHS = 2        # after one warm-up epoch
ENTROPY_EPOCHS = (1, 2)    # steady epochs scored for shuffle quality
# records/epoch may wobble by a few around the floor when the lookahead
# window straddles the measurement edge; far below one batch of slack
FLOOR_TOL_RECORDS = 16

# the nested chain: buffer span doubles each step, so quality and I/O
# must both climb monotonically — strategy name -> constructor kwargs
BLOCK = 256
CHAIN = (
    ["seq"]
    + [f"corgi_b{BLOCK}x{buf}" for buf in (2, 4, 8, 16)]
    + ["lirs"]
)
# off-chain points: reported and extreme-gated, not monotone-gated
EXTRA = ["tfip_q64", f"corgi2_b{BLOCK}x2"]

# SVM convergence axis (dense synthetic, one seed — the ordering gate
# compares a 2.5x objective gap, far above seed noise)
SVM_N = 2048
SVM_DIM = 64
SVM_BATCH = 256
SVM_EPOCHS = 8
SVM_SWEEPS = 4
SVM_SEED = 1
SVM_REF_EPOCHS = 3 * SVM_EPOCHS


def make_strategy(name: str, num_items: int, batch: int, seed: int):
    """One registry for both the I/O sweep and the SVM runs, so the two
    axes describe the same stream generators."""
    if name == "seq":
        return TFIPShuffler(num_items, batch, queue_size=1, seed=seed)
    if name.startswith("tfip_q"):
        return TFIPShuffler(
            num_items, batch, queue_size=int(name[len("tfip_q"):]), seed=seed
        )
    if name == "lirs":
        return LIRSShuffler(
            num_items, batch, seed=seed, avg_instance_bytes=RECORD_BYTES
        )
    if name.startswith(("corgi_", "corgi2_")):
        cls = CorgiSquaredShuffler if name.startswith("corgi2_") else (
            CorgiPileShuffler
        )
        blk, buf = name.split("_b")[1].split("x")
        return cls(
            num_items,
            batch,
            block_records=int(blk),
            buffer_blocks=int(buf),
            seed=seed,
            avg_instance_bytes=RECORD_BYTES,
        )
    raise ValueError(name)


def _measure_io(store: RecordStore, sh, budget: int, ref_first: bytes):
    """One strategy through the real tier: warm-up epoch, then
    ``MEASURED_EPOCHS`` measured epochs of storage records/I/Os."""
    fetcher = PrefetchingFetcher(
        store,
        sh,
        budget_bytes=budget,
        lookahead=LOOKAHEAD,
        gap_bytes=GAP,
        workers=WORKERS,
        policy="belady",
        planner=True,
    )
    warm_first = None
    for e in range(1 + MEASURED_EPOCHS):
        if e == 1:
            fetcher.drain()
            store.stats.reset()
        for k, idx in enumerate(fetcher.batch_iter(e)):
            got = fetcher(idx)
            if e == 1 and k == 0:
                # in-stream byte-identity canary (same rule as
                # benchmarks/multihost_read.py: out-of-stream serves
                # would desync the lookahead window)
                warm_first = bytes(np.asarray(got).reshape(-1))
    fetcher.drain()
    recs = store.stats.batch_records / MEASURED_EPOCHS
    ios = store.stats.batch_ios / MEASURED_EPOCHS
    fetcher.close()
    return {
        "storage_records_per_epoch": recs,
        "storage_ios_per_epoch": ios,
        "storage_bytes_per_epoch": recs * RECORD_BYTES,
        "records_per_io": recs / ios if ios else 0.0,
        "first_batch_identical": warm_first == ref_first,
    }


def _svm_axis(names):
    """Final relative objective per strategy after ``SVM_EPOCHS`` of
    block-wise DCD — the convergence end of the frontier."""
    tmp = tempfile.mkdtemp()
    meta = make_classification_dataset(
        f"{tmp}/frontier_svm.rrec", SVM_N, SVM_DIM, sparse=False, seed=0
    )
    store = RecordStore(meta.path)
    xs, ys = decode_dense_batch(store.read_batch_into(range(SVM_N)), SVM_DIM)
    store.close()

    def run(name: str, epochs: int, seed: int) -> np.ndarray:
        sh = make_strategy(name, SVM_N, SVM_BATCH, seed)
        solver = DCDSolver(SVM_DIM, SVM_N)
        traj = []
        for e in range(epochs):
            for blk in sh.epoch_batches(e):
                solver.solve_block(xs, ys, blk, sweeps=SVM_SWEEPS)
            traj.append(solver.primal_objective(xs, ys))
        return np.minimum.accumulate(traj)

    trajs = {name: run(name, SVM_EPOCHS, SVM_SEED) for name in names}
    ref = run("lirs", SVM_REF_EPOCHS, SVM_SEED + 10)
    f_star = min(min(t[-1] for t in trajs.values()), ref[-1]) * 0.99999
    out = {}
    for name, t in trajs.items():
        rel = (t - f_star) / abs(f_star)
        half = next(
            (i + 1 for i, f in enumerate(rel) if f <= 0.5), SVM_EPOCHS + 1
        )
        out[name] = {
            "svm_rel_final": float(rel[-1]),
            "svm_epochs_to_half": half,
            "svm_rel_traj": [float(v) for v in rel],
        }
    return out


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp()
        path = f"{tmp}/frontier.rrec"
        rng = np.random.default_rng(0)
        with RecordWriter(path, record_size=RECORD_BYTES) as w:
            payload = rng.integers(
                0, 256, size=(N_RECORDS, RECORD_BYTES), dtype=np.uint8
            )
            for i in range(N_RECORDS):
                w.append(payload[i].tobytes())
        store = RecordStore(path)
        total_bytes = float(N_RECORDS * RECORD_BYTES)
        budget = int(BUDGET_FRAC * total_bytes)
        floor = N_RECORDS - int(budget // RECORD_BYTES)

        names = CHAIN + EXTRA
        svm = _svm_axis(names)
        out = {
            "num_records": N_RECORDS,
            "record_bytes": RECORD_BYTES,
            "batch": BATCH,
            "budget_frac": BUDGET_FRAC,
            "floor_records_per_epoch": floor,
            "measured_epochs": MEASURED_EPOCHS,
            "chain": CHAIN,
            "points": {},
        }
        for name in names:
            sh = make_strategy(name, N_RECORDS, BATCH, seed=1)
            q = [
                stream_quality(
                    sh.epoch_index_stream(e), BATCH, N_RECORDS
                )
                for e in ENTROPY_EPOCHS
            ]
            # byte-identity reference: this strategy's own first batch,
            # straight from storage
            first_idx = next(sh.epoch_batches(1))
            ref_first = bytes(store.read_batch_into(first_idx).reshape(-1))
            with Timer() as t:
                io = _measure_io(store, sh, budget, ref_first)
            try:
                plan = sh.io_plan(
                    total_bytes,
                    is_sparse=False,
                    coalesce_gap=GAP,
                    queue_depth=WORKERS,
                    cache_budget_bytes=budget,
                    prefetch_window_bytes=(
                        LOOKAHEAD * BATCH * RECORD_BYTES
                    ),
                    eviction_policy="belady",
                )
            except TypeError:  # BMF/TFIP plans take no tier kwargs
                plan = sh.io_plan(total_bytes, is_sparse=False)
            # the tier model is policy-shaped, not strategy-shaped:
            # under belady every once-per-epoch stream hits exactly c
            # (block_cache_hit_model keeps the pigeonhole form; BMF/TFIP
            # plans carry no tier pricing, so price them directly)
            if isinstance(sh, CorgiPileShuffler):
                model_hit = block_cache_hit_model(
                    BUDGET_FRAC,
                    "belady",
                    block_frac=sh.block_records / N_RECORDS,
                    span_frac=sh.span_records / N_RECORDS,
                )
            else:
                model_hit = cache_hit_model(BUDGET_FRAC, "belady")
            measured_hit = (
                1.0 - io["storage_records_per_epoch"] / N_RECORDS
            )
            point = {
                "on_chain": name in CHAIN,
                "measured_hit_frac": measured_hit,
                "model_hit_frac": model_hit,
                "model_hit_abs_err": abs(measured_hit - model_hit),
                "within_batch_entropy": float(
                    np.mean([x["within_batch_entropy"] for x in q])
                ),
                "successor_gap_entropy": float(
                    np.mean([x["successor_gap_entropy"] for x in q])
                ),
                **io,
                "excess_records_vs_floor": (
                    io["storage_records_per_epoch"] - floor
                ),
                # the Timer wraps warm-up + measured epochs end to end
                "records_per_s": (
                    (1 + MEASURED_EPOCHS) * N_RECORDS / t.seconds
                ),
                "model_cache_hit_fraction": plan.cache_hit_fraction,
                "modeled_epoch_read_s": {
                    dev_name: dev.t_epoch_read(plan)
                    for dev_name, dev in STORAGE_MODELS.items()
                },
                "modeled_preprocess_s": {
                    dev_name: dev.t_preprocess(plan)
                    for dev_name, dev in STORAGE_MODELS.items()
                },
                **svm[name],
            }
            out["points"][name] = point
        store.close()

        pts = out["points"]
        chain = [pts[n] for n in CHAIN]
        frontier_violations = sum(
            not (
                b["within_batch_entropy"] > a["within_batch_entropy"] + 1e-6
                and b["storage_ios_per_epoch"]
                >= a["storage_ios_per_epoch"] * 1.05
            )
            for a, b in zip(chain, chain[1:])
        )
        shuffled = [n for n in names if n != "seq"]
        convergence_inversions = sum(
            pts[n]["svm_rel_final"] >= pts["seq"]["svm_rel_final"]
            for n in shuffled
        )
        corgi2 = pts[f"corgi2_b{BLOCK}x2"]
        extreme_violations = (
            int(pts["seq"]["within_batch_entropy"] > 0.02)
            + int(pts["lirs"]["within_batch_entropy"] < 0.95)
            + int(
                abs(
                    corgi2["within_batch_entropy"]
                    - pts["lirs"]["within_batch_entropy"]
                )
                > 0.02
            )
            # the hybrid's pitch: LIRS-grade entropy at below-LIRS I/O
            + int(
                corgi2["storage_ios_per_epoch"]
                >= pts["lirs"]["storage_ios_per_epoch"]
            )
        )
        out["headline"] = {
            "frontier_violations": frontier_violations,
            # model-vs-measured I/O: the belady tier model must price
            # every strategy's storage reads within 2 % absolute
            "model_violations": sum(
                p["model_hit_abs_err"] > 0.02 for p in pts.values()
            ),
            "max_model_hit_abs_err": max(
                p["model_hit_abs_err"] for p in pts.values()
            ),
            "floor_violations": sum(
                abs(p["excess_records_vs_floor"]) > FLOOR_TOL_RECORDS
                for p in pts.values()
            ),
            "max_abs_excess_records_vs_floor": max(
                abs(p["excess_records_vs_floor"]) for p in pts.values()
            ),
            "convergence_inversions": convergence_inversions,
            "extreme_violations": extreme_violations,
            "byte_mismatches": sum(
                not p["first_batch_identical"] for p in pts.values()
            ),
            "entropy_span": [
                pts[CHAIN[0]]["within_batch_entropy"],
                pts[CHAIN[-1]]["within_batch_entropy"],
            ],
            "io_span": [
                pts[CHAIN[0]]["storage_ios_per_epoch"],
                pts[CHAIN[-1]]["storage_ios_per_epoch"],
            ],
            "seq_vs_best_shuffled_rel_final": [
                pts["seq"]["svm_rel_final"],
                min(pts[n]["svm_rel_final"] for n in shuffled),
            ],
        }
        return out

    return cached("shuffle_frontier", compute, force)


def rows():
    res = run()
    out = []
    for name, p in res["points"].items():
        out.append(
            (
                f"shuffle_frontier/{name}",
                1e6 / p["records_per_s"],
                f"wbe={p['within_batch_entropy']:.3f} "
                f"sge={p['successor_gap_entropy']:.3f} "
                f"ios/ep={p['storage_ios_per_epoch']:.1f} "
                f"recs/ep={p['storage_records_per_epoch']:.0f} "
                f"(floor {res['floor_records_per_epoch']}) "
                f"svm_rel={p['svm_rel_final']:.3f} "
                f"identical={p['first_batch_identical']}",
            )
        )
    h = res["headline"]
    out.append(
        (
            "shuffle_frontier/headline",
            0.0,
            f"frontier_violations={h['frontier_violations']} "
            f"floor_violations={h['floor_violations']} "
            f"convergence_inversions={h['convergence_inversions']} "
            f"extreme_violations={h['extreme_violations']} "
            f"entropy {h['entropy_span'][0]:.3f}->"
            f"{h['entropy_span'][1]:.3f} over ios "
            f"{h['io_span'][0]:.0f}->{h['io_span'][1]:.0f}",
        )
    )
    return out


if __name__ == "__main__":
    run(force=True)
    for r in rows():
        print(",".join(map(str, r)))
