"""§Roofline: render the per-(arch × shape × mesh) roofline table from the
dry-run artifacts (benchmarks/results/dryrun.json).

    compute term    = HLO_FLOPs_per_device / 197e12  (bf16 peak, v5e)
    memory term     = HLO_bytes_per_device / 819e9   (HBM bw)
    collective term = ring-weighted wire bytes per device / 50e9 (ICI link)

Also reports MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the dominant
term, and writes a markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun.json"
MD_OUT = Path(__file__).resolve().parent / "results" / "roofline.md"


def load(variant="baseline", mesh="single"):
    data = json.loads(RESULTS.read_text())
    rows = []
    for key, r in sorted(data.items()):
        if r.get("status") != "ok":
            continue
        if r["variant"] != variant or r["mesh"] != mesh:
            continue
        rows.append(r)
    return rows


def table(variant="baseline", mesh="single"):
    rows = load(variant, mesh)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful/HLO flops | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['t_compute_s']:.4f} | "
            f"{rl['t_memory_s']:.4f} | {rl['t_collective_s']:.4f} | "
            f"{rl['dominant']} | {r['model']['useful_flops_ratio']:.3f} | "
            f"{r['memory']['peak_bytes']/1e9:.2f} |"
        )
    return "\n".join(lines)


def rows():
    out = []
    for r in load():
        rl = r["roofline"]
        bound = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        # roofline fraction: how close the compute term is to the binding term
        frac = rl["t_compute_s"] / bound if bound > 0 else 0.0
        out.append(
            (
                f"roofline/{r['arch']}/{r['shape']}",
                bound * 1e6,
                f"dominant={rl['dominant']} compute_fraction={frac:.3f} "
                f"useful={r['model']['useful_flops_ratio']:.3f}",
            )
        )
    return out


if __name__ == "__main__":
    md = table()
    MD_OUT.write_text(md)
    print(md)
