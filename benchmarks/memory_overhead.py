"""Paper Table 5 + §5.3.3: LIRS memory overhead vs TFIP's shuffle queue.

Pure accounting at the paper's dataset scale, plus our beyond-paper
Feistel assignment (O(1)) for contrast.
"""
from __future__ import annotations

from benchmarks.common import cached
from repro.core.assignment import FeistelAssignment, TableAssignment

# Table 1: (instances, sparse, avg_instance_bytes)
DATASETS = {
    "webspam": (200_000, True, 44_560),
    "epsilon": (400_000, False, 24_000),
    "kdd": (19_264_097, True, 362),
    "higgs": (10_500_000, False, 327),
    "imagenet": (1_281_167, False, 196_608 * 4),
}
TFIP_QUEUE = 10_000


def run(force: bool = False):
    def compute():
        out = {}
        for name, (n, sparse, inst_bytes) in DATASETS.items():
            table = TableAssignment(n).nbytes
            offset = n * 8 if sparse else 0
            out[name] = {
                "random_assign_table_mb": table / 1e6,
                "offset_table_mb": offset / 1e6,
                "feistel_bytes": FeistelAssignment(n).nbytes,
                "tfip_queue_gb": TFIP_QUEUE * inst_bytes / 1e9,
            }
        # paper cross-checks
        out["_paper_checks"] = {
            "webspam_assign_mb_paper": 1.53,
            "kdd_assign_mb_paper": 147.0,
            "imagenet_assign_mb_paper": 9.8,
            "imagenet_tfip_queue_gb_paper": 7.3,
        }
        return out

    return cached("memory_overhead", compute, force)


def rows():
    res = run()
    out = []
    for name, r in res.items():
        if name.startswith("_"):
            continue
        out.append(
            (
                f"memory_overhead/{name}",
                0.0,
                f"assign_table={r['random_assign_table_mb']:.2f}MB "
                f"offset_table={r['offset_table_mb']:.2f}MB "
                f"feistel={r['feistel_bytes']}B tfip_queue={r['tfip_queue_gb']:.2f}GB",
            )
        )
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
