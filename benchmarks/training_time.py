"""Paper Fig 10 (SVM) + Fig 13 (DNN): total training time across
(shuffling method × storage device), via Eq. 1 and the Table 2 device
models, at the PAPER's dataset scale (Table 1).

    T_total = T_pre + (T_load + T_comp − T_overlap) · #Epochs

SVM: no load/compute overlap (§4.3).  DNN: prefetch overlaps loading with
GPU compute, so the unhidden load is max(0, T_load − T_comp).

Epoch counts come from the paper's Tables 3/6 ("paper" mode — reproduces
the figures) or from our measured convergence runs scaled to the paper's
BMF/TFIP epochs ("measured" mode).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from benchmarks.common import cached
from repro.storage.devices import STORAGE_MODELS, StorageModel

GB = 1e9


@dataclass(frozen=True)
class Workload:
    name: str
    instances: int
    total_bytes: float
    sparse: bool
    t_comp_epoch: float  # seconds of pure compute per epoch
    epochs_base: int     # BMF / TFIP epochs (paper tables 3 & 6)
    epochs_lirs: float   # LIRS epochs (paper tables 3 & 6)
    overlap: bool        # DNN overlaps load & compute; SVM does not


# SVM t_comp: LIBLINEAR DCD passes over the data, CPU-bound.  Estimated as
# ~20 inner passes × nnz × 4 FLOP at 5 GFLOP/s effective.
# DNN t_comp: ImageNet epoch on GTX1070 at ~1500/400/120 images/s.
SVM_WORKLOADS = [
    Workload("webspam", 200_000, 8.3 * GB, True, 17.8, 30, 7, False),
    Workload("epsilon", 400_000, 8.9 * GB, False, 19.2, 30, 12, False),
    Workload("kdd", 19_264_097, 6.5 * GB, True, 28.0, 30, 11, False),
    Workload("higgs", 10_500_000, 3.2 * GB, False, 14.0, 30, 17, False),
]
DNN_WORKLOADS = [
    Workload("alexnet", 1_281_167, 234.6 * GB, False, 854.0, 17.5, 13.6, True),
    Workload("overfeat", 1_281_167, 234.6 * GB, False, 3203.0, 11.9, 9.4, True),
    Workload("vgg16", 1_281_167, 234.6 * GB, False, 10676.0, 2.1, 1.6, True),
]

BMF_WRITE_INTERLEAVE = 2.0  # batch-file append streams: seeky seq writes


def epoch_time(t_load: float, t_comp: float, overlap: bool) -> float:
    if overlap:
        return t_comp + max(0.0, t_load - t_comp)  # unhidden load only
    return t_load + t_comp


def baseline_total(w: Workload, dev: StorageModel) -> float:
    """BMF (SVM) / TFIP (DNN): pre-process shuffle + sequential epochs."""
    t_pre = dev.t_seq_read(w.total_bytes) + BMF_WRITE_INTERLEAVE * dev.t_seq_write(
        w.total_bytes
    )
    t_load = dev.t_seq_read(w.total_bytes)
    return t_pre + epoch_time(t_load, w.t_comp_epoch, w.overlap) * w.epochs_base


def lirs_total(w: Workload, dev: StorageModel, epochs: float | None = None) -> float:
    """LIRS: offset-table scan only when sparse; random-read epochs."""
    t_pre = dev.t_seq_read(w.total_bytes) if w.sparse else 0.0
    t_load = dev.t_rand_read(w.instances, w.total_bytes)
    e = w.epochs_lirs if epochs is None else epochs
    return t_pre + epoch_time(t_load, w.t_comp_epoch, w.overlap) * e


# coalesced multi-queue engine configuration (matches benchmarks/batch_read)
MQ_BATCH = 4096
MQ_GAP_BYTES = 4 * 4096
MQ_QUEUE_DEPTH = 8.0


def lirs_mq_total(w: Workload, dev: StorageModel) -> float:
    """LIRS through the coalesced multi-queue batch engine: gap-merged
    range reads shrink the random-I/O count by the expected coalescing
    factor, and reader-thread queue depth scales the device's effective
    random IOPS (up to its ``max_queue_depth``)."""
    from repro.core.shuffler import expected_ragged_coalescing_factor

    avg_bytes = w.total_bytes / w.instances
    factor = expected_ragged_coalescing_factor(
        w.instances, MQ_BATCH, MQ_GAP_BYTES, avg_bytes
    )
    t_pre = dev.t_seq_read(w.total_bytes) if w.sparse else 0.0
    t_load = dev.t_rand_read(
        w.instances / factor, w.total_bytes, queue_depth=MQ_QUEUE_DEPTH
    )
    return t_pre + epoch_time(t_load, w.t_comp_epoch, w.overlap) * w.epochs_lirs


def run(force: bool = False):
    def compute():
        out: Dict[str, Dict] = {"svm": {}, "dnn": {}}
        for kind, workloads, base_name in (
            ("svm", SVM_WORKLOADS, "bmf"),
            ("dnn", DNN_WORKLOADS, "tfip"),
        ):
            for w in workloads:
                ref = baseline_total(w, STORAGE_MODELS["hdd"])
                entry = {}
                for dname, dev in STORAGE_MODELS.items():
                    entry[f"{base_name}+{dname}"] = baseline_total(w, dev) / ref
                    entry[f"lirs+{dname}"] = lirs_total(w, dev) / ref
                    entry[f"lirs_mq+{dname}"] = lirs_mq_total(w, dev) / ref
                entry["t_comp_epoch_s"] = w.t_comp_epoch
                entry["epochs"] = {base_name: w.epochs_base, "lirs": w.epochs_lirs}
                out[kind][w.name] = entry
        # headline averages (paper: −49.9% SVM / −43.5% DNN vs baseline+HDD)
        for kind, base_name in (("svm", "bmf"), ("dnn", "tfip")):
            names = list(out[kind])
            red = [1.0 - out[kind][n]["lirs+optane"] for n in names]
            out[kind]["_avg_reduction_lirs_optane_vs_hdd_baseline"] = sum(red) / len(red)
        return out

    return cached("training_time", compute, force)


def rows():
    res = run()
    out = []
    for kind in ("svm", "dnn"):
        for name, e in res[kind].items():
            if name.startswith("_"):
                continue
            keys = [k for k in e if "+" in k]
            desc = " ".join(f"{k}={e[k]:.3f}" for k in sorted(keys))
            out.append((f"training_time/{kind}/{name}", 0.0, desc))
        avg = res[kind]["_avg_reduction_lirs_optane_vs_hdd_baseline"]
        out.append(
            (
                f"training_time/{kind}/avg_reduction",
                0.0,
                f"LIRS+Optane vs baseline+HDD: -{100*avg:.1f}% total training time",
            )
        )
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
