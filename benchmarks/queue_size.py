"""Paper Fig 3: testing accuracy vs TFIP shuffle-queue size.

Class-sorted on-disk layout + bounded queue ⇒ skewed batches; accuracy
should rise monotonically with queue size, with LIRS (≡ queue = N) at the
top and queue=1 (no shuffling) at the bottom.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cached
from repro.core.shuffler import LIRSShuffler, TFIPShuffler
from repro.dnn.mlp import MLPClassifier, make_clustered_data

N, DIM, CLASSES = 12000, 32, 20
BATCH = 100
EPOCHS = 5
QUEUES = [1, 100, 600, 3000]
SEEDS = (0, 1, 2)


def run(force: bool = False):
    def compute():
        xs, ys, centers = make_clustered_data(N, DIM, CLASSES, seed=42, class_sorted=True, spread=1.0)
        xte, yte, _ = make_clustered_data(
            4000, DIM, CLASSES, seed=99, class_sorted=False, centers=centers
        )
        out = {}
        for q in QUEUES:
            accs = []
            for seed in SEEDS:
                sh = TFIPShuffler(N, BATCH, queue_size=q, seed=seed)
                m = MLPClassifier(DIM, CLASSES, hidden=(64,), seed=seed)
                for e in range(EPOCHS):
                    for idx in sh.epoch_batches(e):
                        m.train_batch(xs[idx], ys[idx])
                accs.append(m.accuracy(xte, yte))
            out[f"queue_{q}"] = {"acc_mean": float(np.mean(accs)), "accs": accs}
        accs = []
        for seed in SEEDS:
            sh = LIRSShuffler(N, BATCH, seed=seed)
            m = MLPClassifier(DIM, CLASSES, hidden=(64,), seed=seed)
            for e in range(EPOCHS):
                for idx in sh.epoch_batches(e):
                    m.train_batch(xs[idx], ys[idx])
            accs.append(m.accuracy(xte, yte))
        out["lirs_full"] = {"acc_mean": float(np.mean(accs)), "accs": accs}
        # memory cost of the queue (paper: 7.3 GB at Q=10000 for ImageNet)
        inst_bytes = DIM * 4
        out["queue_memory_bytes"] = {f"queue_{q}": q * inst_bytes for q in QUEUES}
        return out

    return cached("queue_size", compute, force)


def rows():
    res = run()
    out = []
    for key in [f"queue_{q}" for q in QUEUES] + ["lirs_full"]:
        r = res[key]
        out.append((f"queue_size/{key}", 0.0, f"test_acc={r['acc_mean']:.4f}"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
