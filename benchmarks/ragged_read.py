"""ragged_read — throughput of the ragged arena batch engine.

The variable-length sibling of benchmarks/batch_read, on a synthetic
sparse SVM store (kdd12-style ultra-sparse records via
``make_classification_dataset``; batch 4096 == the paper's n/10 block).
Two layers are measured at several batch sizes:

``read`` — records/s for batch materialization alone:
  * ``naive``      — per-record ``read_batch`` loop (seed baseline)
  * ``coalesced``  — ``read_batch_coalesced``: merged range reads, but
                     per-record Python slicing into ``List[bytes]``
                     (what variable stores used before this engine)
  * ``ragged``     — ``read_batch_ragged``: same merged range reads,
                     scattered into ONE dense arena + (offsets, lengths)
                     via a single vectorized (word-wide) gather
  * ``ragged@N``   — the same fanned across N reader threads

``csr`` — records/s through to *device-ready CSR arrays* (what the DCD
solver consumes): ``coalesced`` + per-record parse vs ``ragged`` +
vectorized ``pack_csr_batch``.  This is the end-to-end hot path the
paper's SVM results ride on, and the acceptance number: ``csr/ragged``
vs ``csr/coalesced`` at batch 4096 (the raw ``read`` ratio is reported
alongside).

Also reports measured coalescing efficiency (records per syscall) next
to the cost model's ``expected_ragged_coalescing_factor`` prediction,
and prices one ragged epoch on each Table 2 device via
``StorageModel.t_epoch_read``.

Emits JSON to benchmarks/results/ragged_read.json (the BENCH trajectory
contract) and harness CSV rows with the speedup over the per-record
slicing path as *derived*.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import cached
from repro.core.location import LocationGenerator
from repro.core.shuffler import LIRSShuffler, expected_ragged_coalescing_factor
from repro.data.synthetic import make_classification_dataset
from repro.storage.devices import STORAGE_MODELS
from repro.storage.record_store import PAGE, RecordStore
from repro.svm.sparse import pack_csr_batch

N_RECORDS = 40_960
DIM = 4096
NNZ_RANGE = (1, 6)   # ultra-sparse (kdd12-style): mean record ~36 B
BATCHES = [256, 1024, 4096]
WORKER_COUNTS = [4, 8]
GAP = 4 * PAGE
REPS = 9


def _interleaved_records_per_s(variants, batch: int, reps: int = REPS):
    """Best-of-``reps`` records/s for every variant, measured round-robin
    so all variants sample the same machine conditions each round (a
    sequential best-of lets one variant catch a quiet period the others
    never see, which skews the ratios on noisy boxes)."""
    best = {name: float("inf") for name, _ in variants}
    for _ in range(reps):
        for name, fn in variants:
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return {name: batch / t for name, t in best.items()}


def run(force: bool = False):
    def compute():
        tmp = tempfile.mkdtemp()
        meta = make_classification_dataset(
            f"{tmp}/ragged.rrec", N_RECORDS, DIM,
            sparse=True, nnz_range=NNZ_RANGE, seed=0,
        )
        store = RecordStore(meta.path)
        LocationGenerator().generate(store)
        rng = np.random.default_rng(1)
        out = {
            "num_records": N_RECORDS,
            "dim": DIM,
            "mean_record_bytes": meta.avg_record_bytes,
            "gap_bytes": GAP,
            "batches": {},
        }
        for b in BATCHES:
            idx = rng.permutation(N_RECORDS)[:b]
            read_variants = [
                ("naive", lambda: store.read_batch(idx)),
                (
                    "coalesced",
                    lambda: store.read_batch_coalesced(idx, gap_bytes=GAP),
                ),
                ("ragged", lambda: store.read_batch_ragged(idx, gap_bytes=GAP)),
            ] + [
                (
                    f"ragged@{wk}",
                    lambda wk=wk: store.read_batch_ragged(
                        idx, gap_bytes=GAP, workers=wk
                    ),
                )
                for wk in WORKER_COUNTS
            ]
            read = _interleaved_records_per_s(read_variants, b)
            csr = _interleaved_records_per_s(
                [
                    (
                        "coalesced",
                        lambda: pack_csr_batch(
                            store.read_batch_coalesced(idx, gap_bytes=GAP)
                        ),
                    ),
                    (
                        "ragged",
                        lambda: pack_csr_batch(
                            store.read_batch_ragged(idx, gap_bytes=GAP)
                        ),
                    ),
                ],
                b,
            )
            store.stats.reset()
            store.read_batch_ragged(idx, gap_bytes=GAP)
            out["batches"][str(b)] = {
                "read": read,
                "csr": csr,
                "records_per_io": store.stats.records_per_io,
                "model_records_per_io": expected_ragged_coalescing_factor(
                    N_RECORDS, b, GAP, meta.avg_record_bytes
                ),
                "read_speedup_vs_slicing": read["ragged"] / read["coalesced"],
                "csr_speedup_vs_slicing": csr["ragged"] / csr["coalesced"],
            }
        # price one ragged epoch on each Table 2 device from the IOPlan
        sh = LIRSShuffler(
            N_RECORDS, BATCHES[-1], avg_instance_bytes=meta.avg_record_bytes
        )
        plan = sh.io_plan(
            meta.total_bytes, is_sparse=True,
            coalesce_gap=GAP, queue_depth=max(WORKER_COUNTS),
        )
        out["modeled_epoch_read_s"] = {
            name: dev.t_epoch_read(plan)
            for name, dev in STORAGE_MODELS.items()
        }
        store.close()
        return out

    return cached("ragged_read", compute, force)


def rows():
    res = run()
    out = []
    for b, entry in res["batches"].items():
        for layer in ("read", "csr"):
            slicing = entry[layer]["coalesced"]
            for variant, rps in entry[layer].items():
                out.append(
                    (
                        f"ragged_read/b{b}/{layer}/{variant}",
                        1e6 / rps,  # us per record
                        f"{rps:,.0f} rec/s x{rps / slicing:.1f} vs slicing "
                        f"coalesce={entry['records_per_io']:.1f} "
                        f"(model {entry['model_records_per_io']:.1f})",
                    )
                )
    return out


if __name__ == "__main__":
    run(force=True)
    for r in rows():
        print(",".join(map(str, r)))
