"""Paper Table 3 + Table 4 + Fig 9: SVM convergence under BMF vs LIRS.

Four synthetic datasets mirroring Table 1's regimes (sparse/dense ×
large/small instances), scaled to CPU budget.  The solver is LIBLINEAR's
dual coordinate descent run block-wise (repro.svm.dcd) — the same
block-minimization structure as the paper's BMF; only the block
composition differs between methods.  Methodology follows §5.2.1: train
BMF for E_MAX epochs, record its best relative function value difference,
then count the epochs LIRS needs to reach the same level (mean over seeds).
"""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import cached
from repro.core.shuffler import BMFShuffler, LIRSShuffler
from repro.data.synthetic import (
    decode_dense_batch,
    decode_sparse_batch,
    make_classification_dataset,
)
from repro.storage.record_store import RecordStore
from repro.svm.dcd import DCDSolver

# (name, n, dim, sparse, nnz) — miniatures of webspam/epsilon/kdd/higgs
DATASETS = [
    ("webspam-like", 4000, 512, True, (64, 192)),
    ("epsilon-like", 4000, 256, False, None),
    ("kdd-like", 4000, 512, True, (4, 16)),
    ("higgs-like", 4000, 28, False, None),
]
NUM_BLOCKS = 10
E_MAX = 15
SWEEPS = 5
SEEDS = (1, 2, 3)


def _load(tmpdir: str, name, n, dim, sparse, nnz, seed=0):
    kw = dict(nnz_range=nnz) if nnz else {}
    meta = make_classification_dataset(
        f"{tmpdir}/{name}.rrec", n, dim, sparse=sparse, seed=seed, **kw
    )
    store = RecordStore(meta.path)
    if sparse:
        from repro.core.location import LocationGenerator

        LocationGenerator().generate(store)
        xs, ys = decode_sparse_batch(store.read_batch(range(n)), dim)
    else:
        # coalesced dense read: one range pread + zero-copy f32 reinterpret
        xs, ys = decode_dense_batch(store.read_batch_into(range(n)), dim)
    store.close()
    return xs, ys


def _run(xs, ys, kind: str, epochs: int, seed: int):
    n, dim = xs.shape
    solver = DCDSolver(dim, n)
    if kind == "bmf":
        sh = BMFShuffler(n, NUM_BLOCKS, seed=seed)
    else:
        sh = LIRSShuffler(n, n // NUM_BLOCKS, seed=seed)
    traj = []
    for e in range(epochs):
        for block in sh.epoch_batches(e):
            solver.solve_block(xs, ys, block, sweeps=SWEEPS)
        traj.append(solver.primal_objective(xs, ys))
    return solver, np.minimum.accumulate(traj)


def run(force: bool = False):
    def compute():
        tmpdir = tempfile.mkdtemp()
        out = {}
        for name, n, dim, sparse, nnz in DATASETS:
            xs, ys = _load(tmpdir, name, n, dim, sparse, nnz)
            ntest = n // 5
            xtr, ytr, xte, yte = xs[:-ntest], ys[:-ntest], xs[-ntest:], ys[-ntest:]
            epochs_l, acc_b, acc_l = [], [], []
            traj_pair = None
            for seed in SEEDS:
                svm_b, tb = _run(xtr, ytr, "bmf", E_MAX, seed)
                svm_l, tl = _run(xtr, ytr, "lirs", E_MAX, seed)
                _, tref = _run(xtr, ytr, "lirs", 3 * E_MAX, seed + 10)
                f_star = min(tb[-1], tl[-1], tref[-1]) * 0.99999
                def rel(t):
                    return (t - f_star) / abs(f_star)
                target = rel(tb)[-1]  # BMF's best level after E_MAX epochs
                el = next((i + 1 for i, f in enumerate(rel(tl)) if f <= target), E_MAX + 1)
                epochs_l.append(el)
                acc_b.append(svm_b.accuracy(xte, yte))
                acc_l.append(svm_l.accuracy(xte, yte))
                if traj_pair is None:
                    traj_pair = (rel(tb).tolist(), rel(tl).tolist())
            out[name] = {
                "epochs_bmf": E_MAX,
                "epochs_lirs_mean": float(np.mean(epochs_l)),
                "epochs_lirs_per_seed": epochs_l,
                "acc_bmf": float(np.mean(acc_b)),
                "acc_lirs": float(np.mean(acc_l)),
                "rel_traj_bmf": traj_pair[0],
                "rel_traj_lirs": traj_pair[1],
            }
        return out

    return cached("svm_convergence", compute, force)


def rows():
    res = run()
    out = []
    for name, r in res.items():
        speedup = r["epochs_bmf"] / max(1.0, r["epochs_lirs_mean"])
        out.append(
            (
                f"svm_convergence/{name}",
                0.0,
                f"epochs BMF={r['epochs_bmf']} LIRS={r['epochs_lirs_mean']:.1f} "
                f"({speedup:.2f}x fewer) acc {r['acc_bmf']:.3f}->{r['acc_lirs']:.3f} "
                f"(d={r['acc_lirs']-r['acc_bmf']:+.4f})",
            )
        )
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
